"""Continuous batching: slot-based scheduler over the decode step.

The static-batch ``ServingEngine`` serves one fixed batch start-to-finish;
real serving workloads trickle in.  This scheduler keeps a fixed number of
SLOTS (the compiled decode batch), admits queued requests into free slots as
they open (per-slot prefill written into the shared cache), decodes all
active slots together, and retires slots on EOS/max-new — vLLM-style
iteration-level scheduling, with ASTRA's sequence-parallel prefill supplying
the time-to-first-token acceleration.

The cache layout is whatever ``serving.cache_backend`` resolves for the
engine's ``cache_mode``.  For the paged layouts the cache is a
block-granular page pool (``serving.kv_cache.PagedKVCache``): admission
additionally blocks until the allocator can cover the request's prompt +
budget (``backend.advance``), prefill writes pages directly (no per-slot
slab copy), and retirement returns the pages.  "paged_vq" stores uint8/16
VQ codes per page — the Appendix-G codes-only cache under per-group block
tables (windowed layers ride the capped "window" table).

With ``prefix_cache=True`` (paged + chunked + all-global attention only)
admission first consults the radix prefix index
(``serving.kv_cache.PrefixIndex``): the longest cached prefix's pages are
shared into the slot's block-table row (refcounted — see ``PageAllocator``),
a partially matching last page forks copy-on-write, and the chunked prefill
plan starts at the first uncached token.  Retirement inserts the prompt's
full pages into the index instead of freeing them; the index LRU-evicts
leaves under allocator pressure.

Admission runs the *chunked prefill pipeline* by default
(``prefill_mode="chunked"``): the prompt walks the bucketed chunk grid
(``serving.steps.plan_chunks`` over ``PREFILL_BUCKETS``) one chunk per
scheduler tick, interleaved with decode — admitting a long prompt never
stalls running decodes, and prefill cost scales with
ceil(len/chunk)*chunk tokens instead of ``max_len`` (Sarathi/DeepSpeed-FastGen
style).  The request owns its slot (and pages) for the whole in-flight
prefill; the decode step sees its block-table rows pointed at scratch until
activation, and the batch-1 chunk cache is merged into the live batched
cache on device when the last chunk lands.  ``prefill_mode="padded"`` keeps
the legacy one-shot full-width prefill (also the fallback under a
seq-sharded mesh or an astra-sim prefill).

All steps are fixed-shape (slot count and max_len are static), so the jitted
steps compile O(1)/O(buckets) times — the admitted slot index and the chunk
start are traced scalars: the prefill merges its batch-1 result into the
engine cache on device, letting the whole cache pytree be donated (in-place
on platforms that alias; no-op on CPU).  Decoding goes through the same
jitted multi-token chunk as ``ServingEngine`` (``repro.serving.steps``):
each ``step()`` advances every active slot by up to ``decode_chunk`` tokens
on device and syncs with the host once, so admission/retirement happen at
chunk boundaries instead of after every token.

**Priority, deadlines and preemption** (the SLA layer):

* ``submit(..., priority=, deadline=)`` — ``priority`` is a class number,
  *lower = more urgent* (default 1, so a ``priority=0`` request outranks
  every default submission); ``deadline`` is an optional per-request TTFT
  SLO in *scheduler steps* (deterministic under replay, unlike wall-clock).
  Admission picks the queued request with the smallest ``(priority,
  deadline, uid)`` — strict priority classes, earliest-deadline-first
  within a class, FIFO within a deadline.  The selected request is
  head-blocking: if its pages aren't grantable (and nothing may be
  preempted for it) admission waits rather than letting smaller requests
  starve it.

* **Preemption** is the release valve for that wait: when the selected
  request has no free slot or can't get pages, the scheduler preempts the
  *lowest-priority* active decode whose class is strictly below the
  candidate's (highest priority number; youngest uid among ties — it has
  done the least work).  Equal-priority decodes are never preempted.

* Under ``preempt_mode="swap"`` (default) the victim's exact cache bytes
  move to a host-side arena (``kv_cache.SwapArena``): its block-table
  rows' pages per pool leaf (``paged_vq`` swaps *code* pages, ~16x smaller
  than fp — the Appendix-G ratio applied to the memory hierarchy), its
  per-slot rows of every dense leaf, its decode cursor, and the per-page
  fp prefill scratch the prefix index would need at retirement.  The
  slot's page references are then dropped through ``backend.release`` —
  refcount-aware, so prefix-shared pages survive via their other owners.
  Re-admission re-grants the same token high-water and scatters the saved
  payload into the fresh pages in one fixed-shape jit
  (``kv_cache.restore_slot``); decode resumes from the saved cursor, so a
  restored request's greedy output is *bitwise identical* to one that was
  never preempted.  ``preempt_mode="recompute"`` drops the cache instead
  and re-admits through the ordinary prefill pipeline over
  ``prompt + output[:-1]`` (the ``CacheBackend.rollback``/prefix-grant
  machinery), resuming from the last emitted token — cheaper in host
  memory, but a prefill-vs-decode numeric path difference means it only
  promises completion, not bitwise parity.  Preemption is refused under a
  sequence-sharded mesh (``backend.preemptible``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sequence_parallel import LOCAL, MeshContext
from repro.models import transformer as tlm
from repro.models.context import StepCtx
from repro.serving import autotune as serving_autotune
from repro.serving import cache_backend as cbe
from repro.serving import kv_cache as kvc
from repro.serving import steps as serving_steps

DEFAULT_DECODE_CHUNK = 4


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # SLA knobs: lower priority number = more urgent (default class 1, so
    # priority 0 outranks every default submission); deadline is a TTFT
    # SLO in scheduler steps (None = best-effort), used for EDF ordering
    # within a class and for goodput accounting — missing it never cancels
    priority: int = 1
    deadline: Optional[float] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    submitted_step: int = -1
    first_token_step: int = -1
    done_step: int = -1
    preemptions: int = 0


@dataclasses.dataclass
class _PendingPrefill:
    """An admission in flight under chunked prefill: the request holds its
    slot (pages already granted) while its prompt walks the chunk grid one
    chunk per scheduler tick, so running decodes never stall behind a long
    prompt.  The batch-1 cache carries recurrent state / slab rows across
    ticks; for paged layouts its pool leaves are re-adopted from the live
    cache before each chunk (decode ticks produce fresh pool arrays)."""

    req: Request
    slot: int
    n: int  # length of ``tokens`` (prompt, or prompt + output[:-1])
    tokens: List[int]  # the sequence being prefilled: the prompt for a
    # fresh admission; prompt + already-emitted output minus the resume
    # token for a ``preempt_mode="recompute"`` re-admission
    plan: List  # [(chunk_start, width)] from serving_steps.plan_chunks
    next_chunk: int
    caches: Any
    last_logits: Any  # (1, V) running last-position logits


class ContinuousBatchingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, mesh_ctx: MeshContext = LOCAL,
                 astra_mode: str = "off", cache_mode: str = "fp",
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 decode_chunk: Optional[int] = None, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 donate: Optional[bool] = None,
                 prefill_mode: Optional[str] = None,
                 prefill_chunk: Optional[int] = None,
                 use_pallas: bool = False,
                 prefix_cache: Optional[bool] = None,
                 speculative: int = 0,
                 draft=None,
                 preempt_mode: str = "swap"):
        if cfg.arch_type in ("vit",):
            raise ValueError("classification models are not generative")
        seq_sharded = (mesh_ctx.seq_axis is not None
                       and mesh_ctx.mesh is not None)
        self.backend = cbe.get_backend(cache_mode, seq_sharded=seq_sharded)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.top_k = top_k
        if decode_chunk is None:
            decode_chunk = (
                serving_autotune.load_decode_chunk(cfg.name, batch=slots)
                or DEFAULT_DECODE_CHUNK)
        self.decode_chunk = max(int(decode_chunk), 1)
        # use_pallas: Pallas-kernel attention hot loops (see ServingEngine)
        self.use_pallas = bool(use_pallas)
        self.prefill_ctx = StepCtx(cfg=cfg, mesh=mesh_ctx, mode="prefill",
                                   astra_mode=astra_mode,
                                   cache_mode=cache_mode,
                                   use_pallas=self.use_pallas)
        self.decode_ctx = StepCtx(cfg=cfg, mesh=mesh_ctx, mode="decode",
                                  astra_mode=astra_mode,
                                  cache_mode=cache_mode,
                                  use_pallas=self.use_pallas)
        if prefill_mode not in (None, "chunked", "padded"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        # an explicit chunked request the engine cannot honor (astra-sim
        # prefill attends through quantized K/V sim the exact chunk step
        # does not reproduce) raises; unset picks the best supported mode
        if prefill_mode == "chunked" and self.prefill_ctx.astra_on:
            raise ValueError(
                "prefill_mode='chunked' cannot run under astra simulation: "
                "the simulated prefill attends through quantized K/V that "
                "the exact chunked step does not reproduce; pass "
                "prefill_mode='padded' or leave it unset")
        self.prefill_mode = prefill_mode or (
            "padded" if self.prefill_ctx.astra_on else "chunked")
        if prefill_chunk is None:
            prefill_chunk = (
                serving_autotune.load_prefill_chunk(cfg.name, batch=slots)
                or serving_steps.DEFAULT_PREFILL_CHUNK)
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.prefill_buckets = serving_steps.prefill_buckets(
            self.prefill_chunk)
        # one cache state for the engine's whole life: page allocators +
        # per-group block tables for the paged layouts, a trivial slab
        # handle otherwise (undersized num_pages => admission waits for
        # pages, not slots)
        self.kv = self.backend.make_state(
            cfg, slots=slots, max_len=max_len, ctx=self.decode_ctx,
            page_size=page_size, num_pages=num_pages, dtype=jnp.float32)
        self.caches = self.kv.init_cache()
        self._bt = self.kv.tables()
        self.admission_stalls = 0  # deferral *episodes* (see _note_stall)
        self._stalled_uid: Optional[int] = None
        if preempt_mode not in ("swap", "recompute"):
            raise ValueError(f"unknown preempt_mode {preempt_mode!r} "
                             f"(choose 'swap' or 'recompute')")
        self.preempt_mode = preempt_mode
        self.preemptions = 0  # preemption events (a request may repeat)
        self.preempt_log: List = []  # (step, uid) per event
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.cur_token = jnp.zeros((slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.step_count = 0
        self.host_syncs = 0
        self._rng = jax.random.PRNGKey(seed)
        # the whole live cache pytree is donated through prefill (the merge
        # happens on device) and through the decode chunk
        prefill_donate = (self.backend.donate_argnums((4,)) if donate is None
                          else ((4,) if donate else ()))
        self._prefill = serving_steps.CountingJit(
            self._prefill_impl, donate_argnums=prefill_donate)
        self._prefill_chunk = serving_steps.make_prefill_chunk(
            self.prefill_ctx, donate=donate)
        # slot-merge for the chunked path: the live cache is donated, the
        # batch-1 prefill result is inserted at the (traced) slot on device
        merge_donate = (self.backend.donate_argnums((0,)) if donate is None
                        else ((0,) if donate else ()))
        self._merge = serving_steps.CountingJit(
            kvc.merge_slot, donate_argnums=merge_donate)
        self._decode_chunk = serving_steps.make_decode_chunk(self.decode_ctx,
                                                             donate=donate)
        # swap-restore for preempted requests: span-shaped payloads and
        # (R, 1, ...) dense rows scatter back at the (traced) slot — one
        # compile covers every restore (kvc.restore_slot)
        restore_donate = (self.backend.donate_argnums((0,)) if donate is None
                          else ((0,) if donate else ()))
        self._restore_jit = serving_steps.CountingJit(
            kvc.restore_slot, donate_argnums=restore_donate)
        # speculative decoding: each tick drafts k tokens per slot by n-gram
        # lookup over the slot's own prompt + output and verifies all k+1
        # positions in one jitted step — variable tokens per slot per tick,
        # committed through the same valid-mask loop as the decode chunk.
        # Paired draft *models* stay with ServingEngine: a second model
        # would need its own slot admission/prefill pipeline here.
        self.spec_k = 0
        self.drafter = None
        self._verify_chunk = None
        if speculative:
            self.spec_k = serving_steps.spec_bucket(int(speculative))
            bound = serving_steps.max_spec_width(cfg, max_len)
            if bound is not None and self.spec_k + 1 > bound:
                raise ValueError(
                    f"speculative width {self.spec_k + 1} exceeds the "
                    f"smallest SWA ring ({bound} slots) — rollback would "
                    f"lap the ring")
            if draft not in (None, "ngram"):
                raise ValueError(
                    "the continuous scheduler drafts by n-gram lookup only; "
                    "paired draft models ride ServingEngine")
            from repro.serving.drafter import NGramDrafter

            self.drafter = NGramDrafter(self.spec_k)
            self._verify_chunk = serving_steps.make_verify_chunk(
                self.decode_ctx, donate=donate)
        self.spec_rounds = 0
        self.spec_active_rows = 0
        self.spec_tokens = 0
        self._pending: Optional[_PendingPrefill] = None
        self.prefill_chunk_ticks = 0  # chunk dispatches (chunked mode)
        self._uid = 0
        # cross-request prefix caching (paged + chunked + all-global only:
        # a shared page id indexes every layer's pool, so reuse is exact
        # only when each layer's KV is a pure function of the token prefix)
        supported = (self.backend.paged and self.prefill_mode == "chunked"
                     and getattr(self.kv, "prefix_shareable", False))
        if prefix_cache and not supported:
            raise ValueError(
                f"prefix_cache=True needs a paged backend with chunked "
                f"prefill and an all-global-attention model "
                f"(cache_mode={self.backend.name!r}, "
                f"prefill_mode={self.prefill_mode!r}, cfg={cfg.name!r})")
        self.prefix_cache = bool(prefix_cache)
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        if self.prefix_cache:
            self.kv.enable_prefix_cache()
            # copy-on-write page fork: src/dst ride as traced scalars, the
            # live cache is donated like every other cache round-trip
            cow_donate = (self.backend.donate_argnums((0,))
                          if donate is None else ((0,) if donate else ()))
            self._cow = serving_steps.CountingJit(
                kvc.copy_page, donate_argnums=cow_donate)
        # per-slot fp scratch snapshots awaiting retirement-time insertion
        # into the prefix index (paged_vq only)
        self._slot_fp: Dict[int, Any] = {}

    # -- jitted steps --------------------------------------------------------
    def _prefill_impl(self, params, tokens, length, slot, live_caches,
                      block_tables):
        """tokens: (1, max_len) padded prompt -> (last_logits, merged caches).

        Slab modes build a throwaway (1, max_len) cache; paged modes adopt
        the engine's live page pools instead and prefill scatters prompt K/V
        straight into the slot's allocated pages.  Either way the batch-1
        result is merged into the live batched cache *on device* at the
        (traced) ``slot`` — one compile covers every admission, and the
        donated ``live_caches`` buffers are updated in place where the
        platform allows."""
        caches = tlm.init_lm_cache(
            self.cfg, 1, self.max_len, self.prefill_ctx, jnp.float32,
            page_size=self.kv.page_size if self.backend.paged else 0,
            num_pages=(self.kv.num_pages_by_group if self.backend.paged
                       else 0))
        if self.backend.paged:
            caches = kvc.adopt_pools(caches, live_caches)
        logits, _, _, caches = tlm.lm_forward(
            params, {"tokens": tokens}, ctx=self.prefill_ctx, caches=caches,
            lengths=jnp.reshape(length, (1,)), block_tables=block_tables)
        last = jnp.take_along_axis(
            logits, (length - 1)[None, None, None].clip(0), axis=1)[:, 0]
        return last, kvc.merge_slot(live_caches, caches, slot)

    # -- slot management -----------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               eos_id: Optional[int] = None, *, priority: int = 1,
               deadline: Optional[float] = None) -> int:
        """Queue a request.  Invalid requests are rejected HERE, not during
        ``step()``: a bad request discovered mid-drain used to either wedge
        the engine (``can_ever_fit`` raising from the queue head) or
        silently truncate the prompt to ``max_len - max_new_tokens - 1`` —
        admitting a garbage all-zeros chunk once ``max_new_tokens`` got
        within 1 of ``max_len``.  Likewise ``max_new_tokens <= 0`` (a
        request that could never emit would pin its slot forever: the
        budget check ``len(output) >= max_new_tokens`` only runs after a
        token lands) and non-positive/NaN deadlines (NaN compares False
        against every TTFT, silently exempting the request from its own
        SLO and poisoning the EDF sort).

        ``priority``: class number, lower = more urgent (default 1).
        ``deadline``: optional TTFT SLO in scheduler steps; orders
        admission within a class (EDF) and feeds goodput accounting."""
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        max_new_tokens = int(max_new_tokens)
        if max_new_tokens <= 0:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens} — the "
                f"request could never emit and would pin its slot forever")
        if int(priority) < 0:
            raise ValueError(f"priority must be >= 0, got {priority}")
        if deadline is not None:
            deadline = float(deadline)
            if not deadline > 0:  # rejects <= 0 and NaN in one comparison
                raise ValueError(
                    f"deadline must be a positive number of scheduler "
                    f"steps, got {deadline}")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} + max_new_tokens "
                f"{max_new_tokens} exceeds max_len={self.max_len}")
        tokens_needed = len(prompt) + max_new_tokens
        if not self.kv.can_ever_fit(tokens_needed):
            raise ValueError(
                f"request needs pages for {tokens_needed} tokens but "
                f"the pool can never hold them")
        self._uid += 1
        self.queue.append(Request(self._uid, prompt, max_new_tokens,
                                  eos_id, priority=int(priority),
                                  deadline=deadline,
                                  submitted_step=self.step_count))
        return self._uid

    def _slot_tables(self, slot: int):
        if self._bt is None:
            return None
        return {name: t[slot:slot + 1] for name, t in self._bt.items()}

    def _resume_seq(self, req: Request) -> List[int]:
        """The token sequence a (re-)admission must prefill: the prompt for
        a fresh request; prompt + emitted output minus the resume token for
        a ``preempt_mode="recompute"`` re-admission (the last emitted token
        becomes ``cur_token`` and is fed back to decode, not prefilled)."""
        return req.prompt + req.output[:-1] if req.output else req.prompt

    def _select_index(self) -> int:
        """Index of the next admission candidate: strict priority classes
        (lower number first), earliest deadline within a class, FIFO (uid)
        within a deadline.  Deadline-less requests sort after any deadline
        in their class."""
        return min(range(len(self.queue)), key=lambda i: (
            self.queue[i].priority,
            self.queue[i].deadline if self.queue[i].deadline is not None
            else float("inf"),
            self.queue[i].uid))

    def _note_stall(self, req: Request) -> None:
        """Count one admission-stall *episode*: the same request deferred
        again on consecutive ticks is one stall, not one per tick (the
        counter is a how-often-did-pressure-bite signal, monotone but not
        tick-inflated).  Cleared when the stalled request admits."""
        if self._stalled_uid != req.uid:
            self.admission_stalls += 1
            self._stalled_uid = req.uid

    def _pick_victim(self, req: Request) -> Optional[int]:
        """Slot of the active decode to preempt for ``req``: the one whose
        priority class is strictly below ``req``'s (largest priority
        number), youngest uid among ties — it has done the least work.
        None when nothing is preemptible: no strictly-lower-priority
        active decode, or a sequence-sharded layout
        (``backend.preemptible``)."""
        if not self.backend.preemptible:
            return None
        best = None
        for slot, r in enumerate(self.active):
            if r is None or r.priority <= req.priority:
                continue
            if best is None or (r.priority, r.uid) > \
                    (self.active[best].priority, self.active[best].uid):
                best = slot
        return best

    def preempt(self, slot: int) -> Request:
        """Preempt the active decode in ``slot`` and requeue it.

        ``preempt_mode="swap"``: snapshot the exact bytes the slot owns
        (pages per pool leaf — code pages under ``paged_vq`` —, dense rows,
        decode cursor, pending fp prefill-scratch snapshots) into the host
        arena, keyed by uid; re-admission restores them bitwise
        (``_restore``).  ``"recompute"``: drop the cache and re-prefill at
        re-admission (``_resume_seq``).  Either way the slot's page
        references are released refcount-aware — pages the prefix index or
        another slot still co-owns survive — and the slot's block-table
        rows point back at scratch."""
        req = self.active[slot]
        if req is None:
            raise ValueError(f"slot {slot} has no active request")
        if self.preempt_mode == "swap":
            entry = self.backend.swap_out(self.kv, slot, self.caches)
            entry.uid = req.uid
            ln, ct = jax.device_get((self.lengths[slot],
                                     self.cur_token[slot]))
            self.host_syncs += 1
            entry.length = int(ln)
            entry.cur_token = int(ct)
            entry.fp_pages = self._slot_fp.pop(slot, None)
            self.kv.arena.stash(entry)
        else:
            self._slot_fp.pop(slot, None)
        self.active[slot] = None
        self.backend.release(self.kv, slot)
        self._bt = self.kv.tables()
        req.preemptions += 1
        self.preemptions += 1
        self.preempt_log.append((self.step_count, req.uid))
        self.queue.append(req)
        return req

    def _restore(self, req: Request, slot: int) -> bool:
        """Re-admit a swapped-out request into ``slot``: re-grant its token
        high-water (preempting lower-priority decodes under pressure, like
        any admission), scatter the arena payload into the fresh page ids
        and merge the dense rows back in one fixed-shape jit, then resume
        decode from the saved cursor — no prefill, no resampling, so the
        greedy continuation is bitwise what the victim would have emitted.
        False (arena entry kept) when pages stay unavailable."""
        entry = self.kv.arena.peek(req.uid)
        while not self.backend.advance(self.kv, slot, entry.granted):
            victim = self._pick_victim(req)
            if victim is None:
                self._note_stall(req)
                return False
            self.preempt(victim)
        entry = self.kv.arena.pop(req.uid)
        self._bt = self.kv.tables()
        dests = self.backend.swap_dests(self.kv, slot, entry)
        self.caches = self._restore_jit(
            self.caches, entry.pages, dests, entry.dense,
            jnp.asarray(slot, jnp.int32))
        if entry.fp_pages is not None:
            self._slot_fp[slot] = entry.fp_pages
        self.active[slot] = req
        self.lengths = self.lengths.at[slot].set(entry.length)
        self.cur_token = self.cur_token.at[slot].set(entry.cur_token)
        if self._stalled_uid == req.uid:
            self._stalled_uid = None
        return True

    def _grant_slot(self, slot: int, req: Request):
        """Page-grant ``req`` into ``slot``; returns
        ``(seq_len, reuse_tokens, fp_pages)``, or None on allocator
        pressure (slot untouched; the prefix index may have LRU-evicted —
        callers route pressure through ``_grant_or_preempt``, which counts
        the stall episode and may preempt instead).  ``submit`` already
        validated the request, so the full prompt is admitted — no
        truncation, no mid-drain raise.  With the prefix cache on, the
        grant routes through ``kv.prefix_grant``: shared pages attach to
        the slot's block-table row first, a partial-page match forks
        copy-on-write, and only the remainder allocates.  A recompute
        re-admission grants (and prefix-matches) over ``_resume_seq`` —
        same total footprint, the emitted output rides along."""
        seq = self._resume_seq(req)
        n = len(seq)
        # admission blocks on allocator pressure, not slot count: the
        # request needs pages for its prompt + full budget (slab
        # backends always have room — advance is a bound check there).
        tokens_needed = min(len(req.prompt) + req.max_new_tokens,
                            self.max_len)
        if self.prefix_cache:
            granted = self.kv.prefix_grant(slot, seq, tokens_needed)
            if granted is None:
                return None  # wait for a retirement to free pages
            reuse, cow, fp_pages = granted
            if cow is not None:
                src, dst = cow
                self.caches = self._cow(self.caches,
                                        jnp.asarray(src, jnp.int32),
                                        jnp.asarray(dst, jnp.int32))
            if reuse:
                self.prefix_hits += 1
                self.prefix_hit_tokens += reuse
        else:
            if not self.backend.advance(self.kv, slot, tokens_needed):
                return None  # wait for a retirement to free pages
            reuse, fp_pages = 0, None
        self._bt = self.kv.tables()
        return n, reuse, fp_pages

    def _grant_or_preempt(self, slot: int, req: Request):
        """``_grant_slot`` with the preemption release valve: on allocator
        pressure, evict the lowest-priority active decode strictly below
        ``req``'s class and retry; once no victim remains, count one stall
        episode and defer."""
        while True:
            granted = self._grant_slot(slot, req)
            if granted is not None:
                if self._stalled_uid == req.uid:
                    self._stalled_uid = None
                return granted
            victim = self._pick_victim(req)
            if victim is None:
                self._note_stall(req)
                return None
            self.preempt(victim)

    def _finish_admission(self, req: Request, slot: int, n: int,
                          last_logits) -> None:
        """Sample the prefill continuation and activate the slot.  A
        recompute re-admission (non-empty ``req.output``) resumes from its
        already-emitted last token instead of sampling a fresh one — the
        prefill covered ``_resume_seq``, and decode picks up exactly where
        the victim stopped."""
        resumed = bool(req.output)
        if resumed:
            tok = req.output[-1]
        else:
            self._rng, sub = jax.random.split(self._rng)
            eos_arr = serving_steps.as_eos_array(req.eos_id, 1)
            first, _ = serving_steps.first_token(
                sub, last_logits, eos_arr, temperature=self.temperature,
                top_k=self.top_k)
            tok = int(first[0])
            self.host_syncs += 1
            req.output.append(tok)
            req.first_token_step = self.step_count
        self.active[slot] = req
        self.lengths = self.lengths.at[slot].set(n)
        self.cur_token = self.cur_token.at[slot].set(tok)
        if not resumed:
            self._maybe_finish(slot, tok)

    def _admit(self) -> None:
        if self.prefill_mode == "padded":
            self._admit_padded()
            return
        self._start_pending()
        self._advance_pending()

    def _free_slot_for(self, req: Request) -> Optional[int]:
        """A slot for ``req``: the first free one, else the slot freed by
        preempting a strictly-lower-priority decode (None when neither
        exists)."""
        slot = next((s for s in range(self.slots)
                     if self.active[s] is None), None)
        if slot is not None:
            return slot
        victim = self._pick_victim(req)
        if victim is None:
            return None
        self.preempt(victim)
        return victim

    def _admit_padded(self) -> None:
        """Legacy one-shot admission: the whole (max_len-padded) prompt
        prefills in a single jitted step, stalling this tick's decode.
        Candidates come in priority/EDF order; the selected request is
        head-blocking (pressure it can't preempt away defers admission
        entirely)."""
        while self.queue:
            req = self.queue[self._select_index()]
            slot = self._free_slot_for(req)
            if slot is None:
                return
            if self.preempt_mode == "swap" and self.kv.arena.holds(req.uid):
                if not self._restore(req, slot):
                    return
                self.queue.remove(req)
                continue
            granted = self._grant_or_preempt(slot, req)
            if granted is None:
                return
            n, _, _ = granted  # padded mode never prefix-caches
            self.queue.remove(req)
            seq = self._resume_seq(req)
            toks = np.zeros((1, self.max_len), np.int32)
            toks[0, :n] = seq[:n]
            last_logits, self.caches = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(n, jnp.int32),
                jnp.asarray(slot, jnp.int32), self.caches,
                self._slot_tables(slot))
            self._finish_admission(req, slot, n, last_logits)

    def _start_pending(self) -> None:
        """Begin a chunked admission when a slot (and its pages) are free —
        preempting a lower-priority decode for either when the candidate
        outranks one (see ``_pick_victim``).  A swapped-out candidate
        restores in place of prefilling.  One admission is in flight at a
        time; its request already owns its pages, so a retirement can't
        steal them mid-prefill."""
        if self._pending is not None or not self.queue:
            return
        req = self.queue[self._select_index()]
        slot = self._free_slot_for(req)
        if slot is None:
            return
        if self.preempt_mode == "swap" and self.kv.arena.holds(req.uid):
            if self._restore(req, slot):
                self.queue.remove(req)
            return
        granted = self._grant_or_preempt(slot, req)
        if granted is None:
            return
        n, reuse, fp_pages = granted
        self.queue.remove(req)
        seq = self._resume_seq(req)
        caches = self.kv.init_cache(1, prefill_scratch=True)
        if self.backend.paged:
            caches = kvc.adopt_pools(caches, self.caches)
        if reuse and self.backend.vq_codes:
            # re-seed the fp prefill-view scratch with the prefix nodes'
            # exact snapshots: the tail chunks attend against the original
            # values, keeping reuse bitwise identical to a cold prefill
            caches = kvc.hydrate_prefill_scratch(
                caches, fp_pages, reuse, self.kv.page_size)
        self._pending = _PendingPrefill(
            req=req, slot=slot, n=n, tokens=seq,
            plan=serving_steps.plan_chunks(n, self.prefill_buckets,
                                           start=reuse),
            next_chunk=0, caches=caches,
            last_logits=jnp.zeros((1, self.cfg.vocab_size), jnp.float32))

    def _advance_pending(self) -> None:
        """Run at most ONE prefill chunk — the scheduler's
        prefill/decode interleave: a long prompt admits over several ticks
        while every active slot keeps decoding."""
        pend = self._pending
        if pend is None:
            return
        if self.backend.paged:
            # decode ticks between chunks produced fresh pool arrays
            pend.caches = kvc.adopt_pools(pend.caches, self.caches)
        s0, w = pend.plan[pend.next_chunk]
        chunk = np.zeros((1, w), np.int32)
        seg = pend.tokens[s0:min(s0 + w, pend.n)]
        chunk[0, :len(seg)] = seg
        pend.last_logits, pend.caches = self._prefill_chunk(
            self.params, jnp.asarray(chunk), jnp.asarray(s0, jnp.int32),
            pend.caches, jnp.asarray([pend.n], jnp.int32),
            pend.last_logits, self._slot_tables(pend.slot),
            history_len=serving_steps.view_bucket(s0 + w, self.max_len))
        self.prefill_chunk_ticks += 1
        pend.next_chunk += 1
        if self.backend.paged:
            self.caches = kvc.adopt_pools(self.caches, pend.caches)
        if pend.next_chunk < len(pend.plan):
            return
        if self.prefix_cache and self.backend.vq_codes:
            # capture the exact fp scratch per prompt page before it is
            # stripped — retirement hands these to the prefix index
            self._slot_fp[pend.slot] = kvc.snapshot_prefill_scratch(
                pend.caches, pend.n, self.kv.page_size)
        fresh = cbe.strip_prefill_scratch(pend.caches)
        if self.backend.paged:
            # the pool leaves inside ``fresh`` are the very arrays
            # ``self.caches`` holds (adopted above): donating self.caches
            # into the merge while fresh still referenced them would hand
            # XLA the same buffer as both donated and non-donated input.
            # The live pools already carry every prefill write, so the
            # merge only needs the dense (batched) leaves.
            fresh = kvc.strip_pool_leaves(fresh)
        self.caches = self._merge(self.caches, fresh,
                                  jnp.asarray(pend.slot, jnp.int32))
        self._pending = None
        self._finish_admission(pend.req, pend.slot, pend.n,
                               pend.last_logits)

    def _maybe_finish(self, slot: int, tok: int) -> bool:
        req = self.active[slot]
        if req is None:
            return False
        if (req.eos_id is not None and tok == req.eos_id) or \
                len(req.output) >= req.max_new_tokens:
            req.done_step = self.step_count
            self.finished.append(req)
            self.active[slot] = None
            if self.prefix_cache:
                # the prompt's full pages move into the prefix index (each
                # node takes its own reference) instead of dying with the
                # slot; release below only drops the slot's references.
                self.kv.prefix_insert(slot, req.prompt,
                                      self._slot_fp.pop(slot, None))
            # the request's remaining page references go back to the free
            # lists; the slot's table rows point at scratch so the
            # fixed-shape decode step keeps writing harmlessly until
            # re-admission (no-op for slab backends).
            self.backend.release(self.kv, slot)
            self._bt = self.kv.tables()
            return True
        return False

    # -- main loop -----------------------------------------------------------
    def step(self) -> int:
        """One scheduler iteration: admit + one on-device decode chunk (up
        to ``decode_chunk`` tokens) for all active slots.  Returns the
        number of tokens emitted this iteration."""
        self._admit()
        n_active = sum(r is not None for r in self.active)
        if n_active == 0:
            self.step_count += 1
            return 0
        remaining = jnp.asarray(
            [(r.max_new_tokens - len(r.output)) if r is not None else 0
             for r in self.active], jnp.int32)
        eos_ids = jnp.asarray(
            [r.eos_id if r is not None and r.eos_id is not None else -1
             for r in self.active], jnp.int32)
        done = jnp.asarray([r is None for r in self.active])
        self._rng, sub = jax.random.split(self._rng)
        bt = self._bt
        if bt is not None and self._pending is not None:
            # a mid-prefill slot already owns pages the decode step must not
            # scribble on (inactive rows re-feed their last token and write
            # it at their stale position): point its rows at scratch until
            # the admission completes.
            bt = {name: t.at[self._pending.slot].set(0)
                  for name, t in bt.items()}
        if self.spec_k:
            # inactive slots get a dummy history (their verify row accepts
            # nothing anyway — done masks every position)
            draft_toks = jnp.asarray(self.drafter.propose_batch(
                [(r.prompt + r.output) if r is not None else [0]
                 for r in self.active]))
            width = self.spec_k + 1
            toks_d, valid_d, cur, self.caches, self.lengths, _, _ = \
                self._verify_chunk(self.params, self.cur_token, draft_toks,
                                   self.caches, self.lengths, remaining,
                                   eos_ids, done, sub, bt,
                                   num_drafted=self.spec_k,
                                   temperature=self.temperature,
                                   top_k=self.top_k)
        else:
            width = self.decode_chunk
            toks_d, valid_d, cur, self.caches, self.lengths, _, _ = \
                self._decode_chunk(self.params, self.cur_token, self.caches,
                                   self.lengths, remaining, eos_ids, done,
                                   sub, bt, num_steps=self.decode_chunk,
                                   temperature=self.temperature,
                                   top_k=self.top_k)
        self.cur_token = cur
        toks_h, valid_h = jax.device_get((toks_d, valid_d))
        self.host_syncs += 1
        self.step_count += 1
        if self.spec_k:
            self.spec_rounds += 1
            self.spec_active_rows += int(valid_h[:, 0].sum())
            self.spec_tokens += int(valid_h.sum())
        emitted = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            for j in range(width):
                if valid_h[slot, j]:
                    req.output.append(int(toks_h[slot, j]))
                    emitted += 1
            if valid_h[slot].any():
                # only this chunk's tokens can retire the slot; a chunk that
                # emitted nothing must not re-check a stale earlier token
                # against EOS (it was already checked when it was emitted).
                self._maybe_finish(slot, req.output[-1])
        return emitted

    def slo_report(self) -> Dict[str, Any]:
        """Deadline bookkeeping over finished requests: a request meets its
        SLO when its TTFT (in scheduler steps) is within its deadline;
        deadline-less requests always count as met.  ``goodput_tokens`` is
        the DeepSpeed-style goodput-under-SLO numerator — tokens emitted by
        SLO-met requests only."""
        met = goodput = with_deadline = 0
        for r in self.finished:
            ttft = r.first_token_step - r.submitted_step
            with_deadline += r.deadline is not None
            if r.deadline is None or ttft <= r.deadline:
                met += 1
                goodput += len(r.output)
        return {"requests": len(self.finished),
                "with_deadline": with_deadline, "met": met,
                "goodput_tokens": goodput}

    @property
    def idle(self) -> bool:
        """No work left: nothing queued (which covers swapped-out requests
        — preemption requeues them), no prefill in flight, no active
        decode."""
        return (not self.queue and self._pending is None
                and all(r is None for r in self.active))

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[str, Any]:
        t0 = time.time()
        decoded = 0
        while not self.idle and self.step_count < max_steps:
            decoded += self.step()
        dt = max(time.time() - t0, 1e-9)
        ttfts = [r.first_token_step - r.submitted_step
                 for r in self.finished]
        return {
            "requests": len(self.finished),
            "tokens": sum(len(r.output) for r in self.finished),
            "steps": self.step_count,
            "wall_s": dt,
            "tok_per_s": decoded / dt,
            "mean_ttft_steps": float(np.mean(ttfts)) if ttfts else 0.0,
            "p50_ttft_steps": float(np.percentile(ttfts, 50)) if ttfts
            else 0.0,
            "p99_ttft_steps": float(np.percentile(ttfts, 99)) if ttfts
            else 0.0,
            "admission_stalls": self.admission_stalls,
            "preemptions": self.preemptions,
            "preempted_requests": len({u for _, u in self.preempt_log}),
            "swap": self.kv.arena.stats(),
            "slo": self.slo_report(),
            "prefill_chunk_ticks": self.prefill_chunk_ticks,
            "spec_rounds": self.spec_rounds,
            "spec_tokens": self.spec_tokens,
            "spec_tokens_per_round": (self.spec_tokens
                                      / max(self.spec_active_rows, 1)
                                      if self.spec_k else None),
            "pages_in_use": self.kv.pages_in_use,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_index": (self.kv.prefix.stats()
                             if self.prefix_cache else None),
        }
