"""Token samplers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(rng: jax.Array, logits: jax.Array, *, temperature: float = 0.0,
                  top_k: int = 0) -> jax.Array:
    """logits: (B, V) -> (B,) int32.  temperature 0 => greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jnp.sort(l, axis=-1)[:, -top_k][:, None]
        l = jnp.where(l < kth, -1e30, l)
    return jax.random.categorical(rng, l, axis=-1).astype(jnp.int32)


def sample_with_scores(rng: jax.Array, logits: jax.Array, *,
                       temperature: float = 0.0, top_k: int = 0):
    """Like :func:`sample_tokens` but also returns the row scores.

    ``logits: (B, V) -> (tokens (B,) int32, logprobs (B, V) float32)``.
    ``logprobs`` is the log-softmax of the *adjusted* distribution the token
    was drawn from (temperature-scaled, top-k-masked), so the verify step of
    speculative decoding can score every drafted position against the exact
    distribution the target would have sampled.  The token itself is bitwise
    identical to ``sample_tokens`` for the same ``rng``/knobs — the greedy
    path shares the same argmax, the sampled path the same categorical draw.
    """
    l = logits.astype(jnp.float32)
    if temperature <= 0.0:
        toks = jnp.argmax(l, axis=-1).astype(jnp.int32)
        return toks, jax.nn.log_softmax(l, axis=-1)
    l = l / temperature
    if top_k:
        kth = jnp.sort(l, axis=-1)[:, -top_k][:, None]
        l = jnp.where(l < kth, -1e30, l)
    toks = jax.random.categorical(rng, l, axis=-1).astype(jnp.int32)
    return toks, jax.nn.log_softmax(l, axis=-1)
