"""Token samplers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(rng: jax.Array, logits: jax.Array, *, temperature: float = 0.0,
                  top_k: int = 0) -> jax.Array:
    """logits: (B, V) -> (B,) int32.  temperature 0 => greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jnp.sort(l, axis=-1)[:, -top_k][:, None]
        l = jnp.where(l < kth, -1e30, l)
    return jax.random.categorical(rng, l, axis=-1).astype(jnp.int32)
