"""Sharding rules: parameter FSDP specs, batch specs, cache specs."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec

# parameters smaller than this are replicated
_REPLICATE_BELOW = 1 << 20


def batch_axes_for(shape: ShapeSpec, mesh: Mesh) -> Tuple[str, ...]:
    """Largest prefix of (pod, data) that divides the global batch."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    chosen = []
    n = 1
    for a in axes:
        if shape.global_batch % (n * mesh.shape[a]) == 0:
            chosen.append(a)
            n *= mesh.shape[a]
    # prefer ('pod','data') ordering but P() wants a tuple
    return tuple(chosen)


def param_pspec(leaf: jax.ShapeDtypeStruct, mesh: Mesh,
                fsdp: str = "2d") -> P:
    """FSDP rule: shard the largest dim divisible by the chosen axis group;
    replicate small leaves.  Leading stacked-layer dims (dim 0 of >=2D
    leaves) are skipped so lax.scan xs stay unsharded on the layer dim.

    fsdp: "2d" (prefer (data, model)), "model", "data", or "none"
    (fully replicated — the paper's per-device full-model assumption)."""
    shape = leaf.shape
    size = math.prod(shape) if shape else 0
    if fsdp == "none" or size < _REPLICATE_BELOW or not shape:
        return P()
    groups = []
    if fsdp == "2d" and "data" in mesh.shape and "model" in mesh.shape:
        groups.append(("data", "model"))
    if fsdp in ("2d", "model") and "model" in mesh.shape:
        groups.append(("model",))
    if fsdp in ("2d", "data") and "data" in mesh.shape:
        groups.append(("data",))
    start = 1 if len(shape) > 1 else 0
    dims = sorted(range(start, len(shape)), key=lambda d: -shape[d])
    for axes in groups:
        n = math.prod(mesh.shape[a] for a in axes)
        for d in dims:
            if shape[d] % n == 0:
                spec = [None] * len(shape)
                spec[d] = axes if len(axes) > 1 else axes[0]
                return P(*spec)
    return P()


def param_shardings(params_shapes, mesh: Mesh, fsdp: str = "2d"):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, param_pspec(l, mesh, fsdp)),
        params_shapes)


def input_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                 seq_axis: Optional[str] = "model"):
    """PartitionSpecs for the input_specs() dict of this (cfg, shape)."""
    b_axes = batch_axes_for(shape, mesh)
    b = b_axes if b_axes else None
    seq = seq_axis if seq_axis in mesh.shape else None

    def spec_for(name: str, leaf) -> P:
        nd = len(leaf.shape)
        if name == "lengths":
            return P(b)
        if name == "token":
            return P(b, None)
        if name in ("tokens", "labels"):
            return P(b, seq) if leaf.shape[1] % _axis(mesh, seq) == 0 else P(b, None)
        if name in ("patch_embeds", "frame_embeds"):
            s = seq if leaf.shape[1] % _axis(mesh, seq) == 0 else None
            return P(b, s, None)
        return P(*([b] + [None] * (nd - 1)))

    return spec_for, b_axes


def _axis(mesh: Mesh, name: Optional[str]) -> int:
    if name is None or name not in mesh.shape:
        return 1 << 62  # force "not divisible" => replicated
    return mesh.shape[name]


def cache_pspecs(cache_shapes, max_len: int, mesh: Mesh,
                 batch_axes: Tuple[str, ...], seq_axis: str = "model"):
    """Specs for a stacked cache pytree.  Heuristic on leaf shapes:
    (R, B, S, ...) with S == max_len -> sequence-sharded over seq_axis;
    everything else replicated except the batch dim."""
    b = batch_axes if batch_axes else None
    n_seq = mesh.shape.get(seq_axis, 1)

    def one(leaf):
        shp = leaf.shape
        spec = [None] * len(shp)
        if len(shp) >= 2:
            spec[1] = b
        if len(shp) >= 3 and shp[2] == max_len and max_len % n_seq == 0:
            spec[2] = seq_axis
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_shapes)
