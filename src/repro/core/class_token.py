"""Distributed Class Tokens (paper §3.3, Theorem 3.2).

Each device holds its own CLS copy which attends to (its own CLS, local
full-precision tokens, vector-quantized remote tokens); content tokens on a
device likewise see their local CLS in full precision.  At the end of the
network the N CLS outputs are mean-pooled (1/N variance reduction).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.mixed_attention import device_mixed_attention


def vit_mixed_attention_sim(
    cls_q: jax.Array,
    cls_k: jax.Array,
    cls_v: jax.Array,
    q: jax.Array,
    k_fp: jax.Array,
    v_fp: jax.Array,
    k_hat: jax.Array,
    v_hat: jax.Array,
    *,
    num_shards: int,
) -> Tuple[jax.Array, jax.Array]:
    """Bidirectional ViT mixed attention with distributed class tokens.

    cls_*: (B, N, H, hd) — per-device class-token projections.
    q/k/v/k_hat/v_hat: (B, T, H, hd) content-token projections (global order).
    Returns (cls_out (B, N, H, hd), content_out (B, T, H, hd)).
    Simulates the N devices with a vmap over shards.
    """
    b, t, h, hd = q.shape
    n = num_shards
    tl = t // n
    offs = jnp.arange(n) * tl

    def shard_reshape(x):
        return x.reshape(b, n, tl, h, hd).swapaxes(0, 1)  # (N, B, tl, H, hd)

    q_s, k_s, v_s = map(shard_reshape, (q, k_fp, v_fp))
    cls_q_s = cls_q.swapaxes(0, 1)[:, :, None]  # (N, B, 1, H, hd)
    cls_k_s = cls_k.swapaxes(0, 1)[:, :, None]
    cls_v_s = cls_v.swapaxes(0, 1)[:, :, None]

    def per_device(q_i, k_i, v_i, cq_i, ck_i, cv_i, off):
        q_all = jnp.concatenate([cq_i, q_i], axis=1)  # (B, 1+tl, H, hd)
        out = device_mixed_attention(
            q_all, k_i, v_i, k_hat, v_hat, off,
            causal=False, extra_kv=(ck_i, cv_i))
        return out[:, :1], out[:, 1:]

    cls_out, content_out = jax.vmap(per_device, in_axes=(0, 0, 0, 0, 0, 0, 0))(
        q_s, k_s, v_s, cls_q_s, cls_k_s, cls_v_s, offs
    )
    cls_out = cls_out[:, :, 0].swapaxes(0, 1)  # (B, N, H, hd)
    content_out = content_out.swapaxes(0, 1).reshape(b, t, h, hd)
    return cls_out, content_out


def pool_class_tokens(cls_emb: jax.Array) -> jax.Array:
    """Aggregate the N distributed class-token outputs (B, N, D) -> (B, D)
    by mean pooling (paper §3.1)."""
    return jnp.mean(cls_emb, axis=1)
