"""ASTRA core: VQ, NAVQ, mixed-precision attention, distributed class tokens,
sequence-parallel exchange, analytic communication model."""
from repro.core import (  # noqa: F401
    astra_block,
    class_token,
    comm_model,
    mixed_attention,
    navq,
    sequence_parallel,
    vq,
)
