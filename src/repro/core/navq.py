"""Noise-Augmented Vector Quantization (paper §3.3, Theorem 3.1).

During fine-tuning, instead of the deterministic quantized embedding x_hat we
use x_tilde = x_hat + lambda * xi, xi ~ N(mu, Sigma) where (mu, Sigma) are the
empirical statistics of the quantization residual eps = x - x_hat, tracked
with an EMA over training batches (diagonal Sigma, matching the i.i.d.
assumption the paper's proof uses).  At inference the noise is omitted.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_residual_stats(dim: int, dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "mean": jnp.zeros((dim,), dtype),
        "var": jnp.ones((dim,), dtype),
        "count": jnp.zeros((), dtype),
    }


def update_residual_stats(
    stats: Dict[str, jax.Array],
    x: jax.Array,
    x_hat: jax.Array,
    decay: float = 0.99,
) -> Dict[str, jax.Array]:
    """EMA update of residual mean/var from a batch.  x, x_hat: (..., D)."""
    res = (x - x_hat).astype(jnp.float32).reshape(-1, x.shape[-1])
    m = jnp.mean(res, axis=0)
    v = jnp.var(res, axis=0)
    # warmup: on the first batches, lean fully on the batch statistics
    alpha = jnp.where(stats["count"] < 1, 0.0, decay)
    return {
        "mean": alpha * stats["mean"] + (1 - alpha) * m,
        "var": alpha * stats["var"] + (1 - alpha) * v,
        "count": stats["count"] + 1,
    }


def add_noise(
    key: jax.Array,
    x_hat: jax.Array,
    stats: Dict[str, jax.Array],
    noise_lambda: float,
) -> jax.Array:
    """x_tilde = x_hat + lambda * xi, xi ~ N(mu, diag(var))."""
    if noise_lambda <= 0.0:
        return x_hat
    xi = stats["mean"] + jnp.sqrt(jnp.maximum(stats["var"], 0.0)) * jax.random.normal(
        key, x_hat.shape, dtype=jnp.float32
    )
    return (x_hat.astype(jnp.float32) + noise_lambda * xi).astype(x_hat.dtype)


def wasserstein2_gaussian_sq(
    m1: jax.Array, v1: jax.Array, m2: jax.Array, v2: jax.Array
) -> jax.Array:
    """W2^2 between diagonal Gaussians (used by tests to check Theorem 3.1)."""
    mean_term = jnp.sum(jnp.square(m1 - m2))
    bures = jnp.sum(jnp.square(jnp.sqrt(v1) - jnp.sqrt(v2)))
    return mean_term + bures


def theorem31_gap(
    m_hat: jax.Array,
    v_hat: jax.Array,
    mu: jax.Array,
    var: jax.Array,
    noise_lambda: float,
) -> Tuple[jax.Array, jax.Array]:
    """Analytic W2^2(P_X, P_Xhat) and W2^2(P_X, P_Xtilde) under the paper's
    Gaussian model (Appendix B): X-hat ~ N(m_hat, diag(v_hat)), residual
    eps ~ N(mu, diag(var)) independent, so X ~ N(m_hat+mu, v_hat+var) and
    X-tilde ~ N(m_hat + l*mu, v_hat + l^2*var).  Theorem 3.1 asserts the
    second return is strictly smaller for l in (0, 1], mu != 0.
    """
    lam = noise_lambda
    m_x, v_x = m_hat + mu, v_hat + var
    w2_hat = wasserstein2_gaussian_sq(m_x, v_x, m_hat, v_hat)
    w2_tilde = wasserstein2_gaussian_sq(
        m_x, v_x, m_hat + lam * mu, v_hat + lam * lam * var
    )
    return w2_hat, w2_tilde
