"""Mixed-Precision Attention (paper §3.2, eq. 1).

Each query attends to a hybrid key/value set: full-precision K/V for tokens
local to the query's device, vector-quantized K-hat/V-hat for non-local
tokens.  Two equivalent formulations are provided:

* ``mixed_attention_sim`` — the *global simulated* view used for training and
  single-process evaluation (this is exactly how the paper trains in
  PyTorch): both score matrices are computed and combined with the
  block-diagonal locality mask M of eq. (1).  Differentiable.

* ``device_mixed_attention`` — the *per-device* runtime view used inside
  ``shard_map``: the device assembles K_eff by splicing its local FP K into
  the globally dequantized K-hat and runs one attention.  Mathematically
  identical (tests assert parity), but with a single score matmul.

Supports GQA, causal masks on global positions, sliding windows, gemma2-style
logit soft-capping, and extra full-precision tokens (distributed class
tokens prepend one FP row/col per device).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _gqa_scores(q: jax.Array, k: jax.Array, scale: float) -> jax.Array:
    """q: (B, Tq, H, hd), k: (B, Tk, Hkv, hd) -> (B, H, Tq, Tk).

    Operands stay in their storage dtype (bf16 on the pod) with fp32
    accumulation via ``preferred_element_type`` — exactly what the MXU does
    natively.  Casting the operands to fp32 first would materialise a full
    fp32 copy of the KV cache in HBM every step (§Perf pair-B iteration 2:
    -40%% decode memory term)."""
    b, tq, h, hd = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, tq, hkv, rep, hd)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                   preferred_element_type=jnp.float32)
    return (s * scale).reshape(b, h, tq, k.shape[1])


def _gqa_combine(w: jax.Array, v: jax.Array) -> jax.Array:
    """w: (B, H, Tq, Tk) fp32, v: (B, Tk, Hkv, hd) -> (B, Tq, H, hd)."""
    b, h, tq, tk = w.shape
    hkv = v.shape[2]
    rep = h // hkv
    wg = w.reshape(b, hkv, rep, tq, tk)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", wg.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, tq, h, v.shape[-1])


def make_mask(
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    k_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Boolean (.., Tq, Tk) mask of allowed attention edges."""
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window and window > 0:
        m &= q_pos[:, None] - k_pos[None, :] < window
    if k_valid is not None:
        m &= k_valid[None, :]
    return m


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    k_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference full-precision attention (the non-ASTRA baseline)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = _softcap(_gqa_scores(q, k, scale), softcap)
    mask = make_mask(q_pos, k_pos, causal=causal, window=window, k_valid=k_valid)
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return _gqa_combine(w, v).astype(q.dtype)


# ---------------------------------------------------------------------------
# Global simulated view (training)
# ---------------------------------------------------------------------------


def mixed_attention_sim(
    q: jax.Array,
    k_fp: jax.Array,
    v_fp: jax.Array,
    k_hat: jax.Array,
    v_hat: jax.Array,
    *,
    num_shards: int,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    shard_bounds: Optional[jax.Array] = None,
) -> jax.Array:
    """Eq. (1) with the locality mask M.

    q/k/v: (B, T, H(.kv), hd) in the *global* token order; queries in shard i
    use full-precision scores/values against keys in shard i and quantized
    ones elsewhere.  ``shard_bounds`` optionally gives uneven shard start
    offsets (heterogeneous devices, Appendix D), shape (num_shards + 1,).
    """
    t = q.shape[1]
    t_k = k_fp.shape[1]
    pos = jnp.arange(t)
    pos_k = jnp.arange(t_k)
    if shard_bounds is None:
        shard_q = pos * num_shards // t
        shard_k = pos_k * num_shards // t_k  # cross-attn: co-resident shards
    else:
        shard_q = jnp.searchsorted(shard_bounds, pos, side="right") - 1
        shard_k = shard_q if t == t_k else pos_k * num_shards // t_k
    local = shard_q[:, None] == shard_k[None, :]  # same-device mask M

    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s_fp = _softcap(_gqa_scores(q, k_fp, scale), softcap)
    s_hat = _softcap(_gqa_scores(q, k_hat, scale), softcap)
    s = jnp.where(local, s_fp, s_hat)
    mask = make_mask(pos, pos_k, causal=causal and t == t_k, window=window)
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = _gqa_combine(jnp.where(local, w, 0.0), v_fp) + _gqa_combine(
        jnp.where(local, 0.0, w), v_hat
    )
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Per-device runtime view (shard_map body)
# ---------------------------------------------------------------------------


def splice_local(
    x_hat_full: jax.Array, x_local: jax.Array, offset: jax.Array
) -> jax.Array:
    """Replace the [offset : offset+T_loc] slice of the dequantized global
    tensor with the device's full-precision local tensor (axis 1)."""
    return jax.lax.dynamic_update_slice_in_dim(
        x_hat_full, x_local.astype(x_hat_full.dtype), offset, axis=1
    )


def device_mixed_attention(
    q_local: jax.Array,
    k_local: jax.Array,
    v_local: jax.Array,
    k_hat_full: jax.Array,
    v_hat_full: jax.Array,
    offset: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    extra_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> jax.Array:
    """One device's mixed-precision attention.

    q_local/k_local/v_local: (B, T_loc, ...) for this shard;
    k_hat_full/v_hat_full: (B, T, ...) dequantized for the whole sequence;
    offset: this shard's global start position.
    extra_kv: optional (k, v) of full-precision prefix tokens (distributed
    class token) prepended outside the positional masking.
    """
    t = k_hat_full.shape[1]
    t_loc = q_local.shape[1]
    k_eff = splice_local(k_hat_full, k_local, offset)
    v_eff = splice_local(v_hat_full, v_local, offset)
    q_pos = offset + jnp.arange(t_loc)
    k_pos = jnp.arange(t)

    if extra_kv is not None:
        ek, ev = extra_kv
        n_extra = ek.shape[1]
        k_eff = jnp.concatenate([ek.astype(k_eff.dtype), k_eff], axis=1)
        v_eff = jnp.concatenate([ev.astype(v_eff.dtype), v_eff], axis=1)
        # extra tokens sit "before" every position and are never masked out
        k_pos = jnp.concatenate([jnp.full((n_extra,), -1), k_pos])

    scale = 1.0 / jnp.sqrt(q_local.shape[-1]).astype(jnp.float32)
    s = _softcap(_gqa_scores(q_local, k_eff, scale), softcap)
    mask = make_mask(q_pos, k_pos, causal=causal, window=window)
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return _gqa_combine(w, v_eff).astype(q_local.dtype)


def blocked_device_mixed_attention(
    q_local: jax.Array,
    k_local: jax.Array,
    v_local: jax.Array,
    k_hat_full: jax.Array,
    v_hat_full: jax.Array,
    offset: jax.Array,
    *,
    chunk: int,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """Flash-style blocked version of ``device_mixed_attention`` (§Perf).

    The unblocked path materialises the (B, H, T_loc, T) fp32 score matrix
    through a ~6-op masked-softmax chain — the dominant HBM term for every
    attention arch at 32k context.  This version scans KV chunks with an
    online softmax so only (B, H, T_loc, chunk) is ever live; it is the
    pure-JAX mirror of the Pallas ``mixed_flash_attention`` kernel (which
    additionally dequantizes VQ codes in VMEM on the TPU target).
    """
    t = k_hat_full.shape[1]
    t_loc = q_local.shape[1]
    b, _, h, hd = q_local.shape
    hkv = k_local.shape[2]
    c = min(chunk, t)
    if t % c:
        return device_mixed_attention(
            q_local, k_local, v_local, k_hat_full, v_hat_full, offset,
            causal=causal, window=window, softcap=softcap)
    nc = t // c

    k_eff = splice_local(k_hat_full, k_local, offset)
    v_eff = splice_local(v_hat_full, v_local, offset)
    kc = jnp.moveaxis(k_eff.reshape(b, nc, c, hkv, hd), 1, 0)
    vc = jnp.moveaxis(v_eff.reshape(b, nc, c, hkv, hd), 1, 0)
    q_pos = offset + jnp.arange(t_loc)
    scale = 1.0 / jnp.sqrt(q_local.shape[-1]).astype(jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        k_i, v_i, ci = xs
        s = _softcap(_gqa_scores(q_local, k_i, scale), softcap)
        k_pos = ci * c + jnp.arange(c)
        mask = make_mask(q_pos, k_pos, causal=causal, window=window)
        s = jnp.where(mask, s, NEG_INF)  # (B, H, T_loc, c)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * jnp.moveaxis(corr, 1, 2)[..., None] + _gqa_combine(p, v_i)
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, t_loc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, t_loc), jnp.float32)
    a0 = jnp.zeros((b, t_loc, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc, vc, jnp.arange(nc)))
    out = acc / jnp.maximum(jnp.moveaxis(l, 1, 2)[..., None], 1e-30)
    return out.astype(q_local.dtype)


# ---------------------------------------------------------------------------
# Decode: distributed partial-softmax merge (beyond-paper, DESIGN.md §2)
# ---------------------------------------------------------------------------


def partial_attention_stats(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    k_valid: jax.Array,
    softcap: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-shard flash-decoding statistics.

    q: (B, 1, H, hd); k/v: (B, T_loc, Hkv, hd); k_valid: (B, T_loc) bool.
    Returns (m, l, o): running max (B, H, 1), sum-exp (B, H, 1) and the
    un-normalised weighted value (B, 1, H, hd).  Merging across shards:
    m* = max_i m_i; l* = sum_i l_i exp(m_i - m*); out = sum_i o_i exp(m_i-m*) / l*.
    """
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = _softcap(_gqa_scores(q, k, scale), softcap)  # (B, H, 1, T)
    s = jnp.where(k_valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B, H, 1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(k_valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)  # (B, H, 1)
    o = _gqa_combine(p, v)  # (B, 1, H, hd) un-normalised
    return m, l, o


def chunk_partial_stats(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    valid: jax.Array,
    softcap: float = 0.0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-shard flash statistics for a W-wide chunk of queries.

    q: (B, W, H, hd); k/v: (B, T_loc, Hkv, hd); valid: (B, W, T_loc) bool —
    per-query causal/window/slot validity.  Returns (m, l, o) shaped
    (B, H, W), (B, H, W), (B, W, H, hd): the W-wide generalization of
    ``partial_attention_stats``, mergeable by ``merge_partial_stats``
    unchanged (its ``moveaxis(·, 1, 2)`` reshuffles are width-agnostic).
    """
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    s = _softcap(_gqa_scores(q, k, scale), softcap)  # (B, H, W, T)
    s = jnp.where(valid[:, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)  # (B, H, W)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None], p, 0.0)
    l = jnp.sum(p, axis=-1)  # (B, H, W)
    o = _gqa_combine(p, v)  # (B, W, H, hd) un-normalised
    return m, l, o


def merge_partial_stats(
    m: jax.Array, l: jax.Array, o: jax.Array, axis_name: str
) -> jax.Array:
    """Merge flash-decoding partials across a mesh axis (inside shard_map)."""
    m_star = jax.lax.pmax(m, axis_name)  # (B, H, 1)
    corr = jnp.exp(m - m_star)
    l_star = jax.lax.psum(l * corr, axis_name)
    o_corr = o * jnp.moveaxis(corr, 1, 2)[..., None]  # (B,1,H,1) broadcast
    o_star = jax.lax.psum(o_corr, axis_name)
    return o_star / jnp.maximum(jnp.moveaxis(l_star, 1, 2)[..., None], 1e-30)
