"""ASTRA attention integration: sim (training) and SPMD (runtime) paths.

``quantize_mode="kv"`` (Llama setting, Appendix G C=2): K and V are
quantized separately after RoPE; receivers need only the two codebooks.
``quantize_mode="input"`` (ViT/GPT2 setting, C=1): the block input X is
quantized once and K-hat/V-hat derived by projection — handled in the model
block via ``quantize_with_navq`` since it needs the projection weights.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ASTRAConfig
from repro.core import navq, vq
from repro.core.mixed_attention import (
    blocked_device_mixed_attention,
    device_mixed_attention,
    full_attention,
    mixed_attention_sim,
)
from repro.core.sequence_parallel import MeshContext, exchange_codes, shard_offset


# ---------------------------------------------------------------------------
# Shared helper: quantize + straight-through + NAVQ noise
# ---------------------------------------------------------------------------


def quantize_with_navq(
    params: Dict[str, jax.Array],
    x: jax.Array,
    spec: vq.VQSpec,
    *,
    noise_lambda: float = 0.0,
    train: bool = False,
    rng: Optional[jax.Array] = None,
    stats: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (x_hat, codes, commit_sum).  In training, x_hat carries the
    straight-through gradient and NAVQ noise; at inference it is the plain
    deterministic dequantization (paper §3.3)."""
    x_hat, codes, commit = vq.quantize_st(params, x, spec)
    if train and noise_lambda > 0.0 and rng is not None and stats is not None:
        x_hat = navq.add_noise(rng, x_hat, stats, noise_lambda)
    return x_hat, codes, commit


# ---------------------------------------------------------------------------
# Sim path (global view; used by the trainer and smoke tests)
# ---------------------------------------------------------------------------


def astra_kv_attention_sim(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    vq_params_k: Dict[str, jax.Array],
    vq_params_v: Dict[str, jax.Array],
    astra: ASTRAConfig,
    *,
    num_shards: int,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    train: bool = False,
    rng: Optional[jax.Array] = None,
    navq_stats_k: Optional[Dict[str, jax.Array]] = None,
    navq_stats_v: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mixed-precision attention, quantize_mode="kv", global simulated view."""
    b, t, hkv, hd = k.shape
    spec = vq.VQSpec(hkv * hd, astra.groups, astra.codebook_size)
    rk, rv = (jax.random.split(rng) if rng is not None else (None, None))

    k_flat, v_flat = k.reshape(b, t, -1), v.reshape(b, t, -1)
    k_hat_f, k_codes, commit_k = quantize_with_navq(
        vq_params_k, k_flat, spec, noise_lambda=astra.noise_lambda,
        train=train, rng=rk, stats=navq_stats_k)
    v_hat_f, v_codes, commit_v = quantize_with_navq(
        vq_params_v, v_flat, spec, noise_lambda=astra.noise_lambda,
        train=train, rng=rv, stats=navq_stats_v)
    k_hat = k_hat_f.reshape(b, t, hkv, hd)
    v_hat = v_hat_f.reshape(b, t, hkv, hd)

    out = mixed_attention_sim(
        q, k, v, k_hat, v_hat, num_shards=num_shards,
        causal=causal, window=window, softcap=softcap)
    aux = {
        "commit": commit_k + commit_v,
        "k_codes": k_codes,
        "v_codes": v_codes,
        # residuals for the NAVQ EMA statistics (stop-grad views)
        "k_pair": (jax.lax.stop_gradient(k_flat), jax.lax.stop_gradient(k_hat_f)),
        "v_pair": (jax.lax.stop_gradient(v_flat), jax.lax.stop_gradient(v_hat_f)),
    }
    return out, aux


# ---------------------------------------------------------------------------
# SPMD path (inside pjit; shard_map over the sequence axis)
# ---------------------------------------------------------------------------


def astra_kv_attention_spmd(
    ctx: MeshContext,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    codebook_k: jax.Array,
    codebook_v: jax.Array,
    astra: ASTRAConfig,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    neighbor_window_exchange: bool = False,
    chunk: int = 0,
) -> jax.Array:
    """Runtime mixed-precision attention over a sequence-sharded mesh axis.

    q/k/v are global (pjit-view) arrays of shape (B, T, H(.kv), hd) sharded
    P(batch_axes, seq_axis, None, None).  The only cross-device traffic is
    the all-gather of packed VQ codes (plus, for SWA layers with
    ``neighbor_window_exchange``, a ring exchange limited to the shards the
    window can reach — a beyond-paper collective-schedule optimisation).
    """
    if ctx.seq_axis is None or ctx.mesh is None:
        raise ValueError("SPMD path requires a sequence-sharded MeshContext")
    b, t, hkv, hd = k.shape
    spec = vq.VQSpec(hkv * hd, astra.groups, astra.codebook_size)
    axis = ctx.seq_axis
    bspec = ctx.batch_axes if ctx.batch_axes else None

    def body(q_l, k_l, v_l, cb_k, cb_v):
        bl, tl = k_l.shape[0], k_l.shape[1]
        pk, pv = {"codebook": cb_k}, {"codebook": cb_v}
        k_codes = vq.encode(pk, k_l.reshape(bl, tl, -1), spec)
        v_codes = vq.encode(pv, v_l.reshape(bl, tl, -1), spec)
        if astra.pack_codes:
            k_codes = vq.pack_codes(k_codes, spec)
            v_codes = vq.pack_codes(v_codes, spec)
        kc = vq.unpack_codes(exchange_codes(k_codes, axis))
        vc = vq.unpack_codes(exchange_codes(v_codes, axis))
        k_hat = vq.decode(pk, kc, spec).reshape(bl, t, hkv, hd)
        v_hat = vq.decode(pv, vc, spec).reshape(bl, t, hkv, hd)
        off = shard_offset(axis, tl)
        if chunk:
            return blocked_device_mixed_attention(
                q_l, k_l, v_l, k_hat, v_hat, off, chunk=chunk,
                causal=causal, window=window, softcap=softcap)
        return device_mixed_attention(
            q_l, k_l, v_l, k_hat, v_hat, off,
            causal=causal, window=window, softcap=softcap)

    qspec = P(bspec, axis, None, None)
    return shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(qspec, qspec, qspec, P(), P()),
        out_specs=qspec,
        check_vma=False,
    )(q, k, v, codebook_k, codebook_v)


def sp_full_attention_spmd(
    ctx: MeshContext,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    chunk: int = 0,
) -> jax.Array:
    """Baseline sequence parallelism (Voltage-style): all-gather the
    FULL-PRECISION K/V over the sequence axis.  Numerically exact; used when
    ASTRA is disabled and as the paper's SP baseline for roofline
    comparisons."""
    if ctx.seq_axis is None or ctx.mesh is None:
        raise ValueError("SPMD path requires a sequence-sharded MeshContext")
    t = k.shape[1]
    axis = ctx.seq_axis
    bspec = ctx.batch_axes if ctx.batch_axes else None

    def body(q_l, k_l, v_l):
        tl = q_l.shape[1]
        k_full = jax.lax.all_gather(k_l, axis, axis=1, tiled=True)
        v_full = jax.lax.all_gather(v_l, axis, axis=1, tiled=True)
        off = shard_offset(axis, tl)
        if chunk:
            # blocked path: splice is a no-op (k_full already exact)
            return blocked_device_mixed_attention(
                q_l, k_l, v_l, k_full, v_full, off, chunk=chunk,
                causal=causal, window=window, softcap=softcap)
        q_pos = off + jnp.arange(tl)
        k_pos = jnp.arange(t)
        return full_attention(
            q_l, k_full, v_full, q_pos=q_pos, k_pos=k_pos,
            causal=causal, window=window, softcap=softcap)

    qspec = P(bspec, axis, None, None)
    return shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(qspec, qspec, qspec),
        out_specs=qspec,
        check_vma=False,
    )(q, k, v)
