"""Sequence-parallel plumbing: mesh context, code exchange, carry exchange.

ASTRA's wire protocol per Transformer block is a single all-gather of int
VQ codes over the sequence ("model") mesh axis — `exchange_codes`.  For
attention-free layers (SSD / RG-LRU) the inter-device object is the linear
recurrence carry, exchanged with `distributed_carry` (a prefix-combine over
the per-device (decay, state) pairs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Names of the mesh axes a model step runs under.

    batch_axes: axes the global batch is sharded over (('pod','data') or
    ('data',)).  seq_axis: axis the sequence dim is sharded over ('model'),
    or None when running without sequence parallelism (smoke tests).
    """

    mesh: Optional[object] = None  # jax.sharding.Mesh
    batch_axes: Tuple[str, ...] = ()
    seq_axis: Optional[str] = None

    @property
    def num_seq_shards(self) -> int:
        if self.mesh is None or self.seq_axis is None:
            return 1
        return self.mesh.shape[self.seq_axis]

    def batch_spec(self) -> P:
        return P(self.batch_axes if self.batch_axes else None)


# single-device context used by smoke tests / the trainer's simulated mode
LOCAL = MeshContext()


def constrain_seq_sharded(x: jax.Array, ctx: "MeshContext") -> jax.Array:
    """Pin an activation to P(batch_axes, seq_axis, None...) sharding.

    Without this, XLA SPMD propagates FSDP *weight* shardings into the
    activations of the layer scan body (e.g. d_ff or vocab split over all
    chips), then emits 'involuntary full rematerialization' all-gathers of
    the full global activation inside the loop — a >100x collective-term
    regression found via the dry-run roofline (EXPERIMENTS.md §Perf it.0).
    Constraining the scan-body inputs keeps activations sequence-sharded and
    makes the partitioner all-gather the (much smaller) weights instead.
    """
    if ctx is None or ctx.mesh is None or ctx.seq_axis is None:
        return x
    if x.ndim < 3 or x.shape[1] % ctx.mesh.shape[ctx.seq_axis]:
        return x
    from jax.sharding import NamedSharding

    b = ctx.batch_axes if ctx.batch_axes else None
    spec = P(*([b, ctx.seq_axis] + [None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def shard_offset(axis_name: str, t_loc: int) -> jax.Array:
    """Global start position of this device's sequence shard (in shard_map)."""
    return jax.lax.axis_index(axis_name) * t_loc


def exchange_codes(codes_local: jax.Array, axis_name: str) -> jax.Array:
    """All-gather VQ codes along the sequence axis (inside shard_map).

    codes_local: (B, T_loc, ...) int -> (B, T, ...).  This is ASTRA's entire
    per-block communication: log2(K)-bit codes instead of D*r-bit embeddings.
    """
    return jax.lax.all_gather(codes_local, axis_name, axis=1, tiled=True)


def distributed_carry(
    a_local: jax.Array, b_local: jax.Array, axis_name: str
) -> Tuple[jax.Array, jax.Array]:
    """Incoming carry for a device-sharded linear recurrence.

    The recurrence h_t = a_t * h_{t-1} + b_t composed over a device's whole
    shard yields the pair (A_i, B_i) with h_out = A_i * h_in + B_i.  Given
    each device's local pair, returns (A_prefix, B_prefix) such that this
    device's incoming carry is h_in = A_prefix * h0 + B_prefix (h0 = 0 at
    sequence start).  Exchange volume: one (a, b) pair per device — tiny.
    """
    n = compat.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    a_all = jax.lax.all_gather(a_local, axis_name)  # (N, ...)
    b_all = jax.lax.all_gather(b_local, axis_name)

    def combine(carry, ab):
        a_c, b_c = carry
        a_i, b_i = ab
        return (a_i * a_c, a_i * b_c + b_i), None

    def fold(i, carry):
        a_c, b_c = carry
        take = i < idx
        a_i = jnp.where(take, a_all[i], jnp.ones_like(a_local))
        b_i = jnp.where(take, b_all[i], jnp.zeros_like(b_local))
        return (a_i * a_c, a_i * b_c + b_i)

    del combine
    init = (jnp.ones_like(a_local), jnp.zeros_like(b_local))
    a_p, b_p = jax.lax.fori_loop(0, n, fold, init)
    return a_p, b_p


def fpar(shard_sizes: jax.Array) -> jax.Array:
    """Full-Precision Attention Rate (Appendix D, eq. 35):
    FPAR = sum_k n_k^2 / N^2."""
    n = jnp.sum(shard_sizes)
    return jnp.sum(jnp.square(shard_sizes.astype(jnp.float32))) / jnp.square(
        n.astype(jnp.float32)
    )


def partition_tokens(t: int, num_shards: int, weights=None):
    """Token partition bounds across devices.  Uniform unless ``weights``
    (relative device capacities, Appendix D heterogeneous setting) given.
    Returns an int array of shard start offsets, length num_shards+1."""
    import numpy as np

    if weights is None:
        step = t // num_shards
        bounds = np.arange(num_shards + 1) * step
        bounds[-1] = t
        return bounds
    w = np.asarray(weights, dtype=np.float64)
    cuts = np.round(np.cumsum(w) / w.sum() * t).astype(np.int64)
    return np.concatenate([[0], cuts])
