"""Grouped vector quantization (paper §2, §3.2).

A ``GroupedVQ`` over dimension D with G groups holds a codebook of shape
(G, K, D/G).  ``encode`` maps x -> int32 codes (..., G) by nearest-centroid
lookup per group; ``decode`` reconstructs x-hat by table lookup.  Vanilla VQ
is G=1.  Training uses the straight-through estimator, the VQ-VAE commitment
loss ``beta * ||x - sg(x_hat)||^2`` and EMA codebook updates; codebooks are
k-means initialised from pretrained activations (paper §3.2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class VQSpec:
    dim: int
    groups: int = 1
    codebook_size: int = 1024

    def __post_init__(self):
        if self.dim % self.groups:
            raise ValueError(f"dim {self.dim} not divisible by groups {self.groups}")

    @property
    def group_dim(self) -> int:
        return self.dim // self.groups

    @property
    def bits_per_token(self) -> int:
        """Wire bits for one token's codes (paper: G * log2 K)."""
        return self.groups * (self.codebook_size - 1).bit_length()


# ---------------------------------------------------------------------------
# Params / state
# ---------------------------------------------------------------------------


def init(key: jax.Array, spec: VQSpec, dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Random-normal init; real deployments call ``kmeans_init`` afterwards."""
    cb = jax.random.normal(key, (spec.groups, spec.codebook_size, spec.group_dim), dtype)
    return {"codebook": cb}


def init_ema_state(spec: VQSpec, dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "cluster_size": jnp.zeros((spec.groups, spec.codebook_size), dtype),
        "cluster_sum": jnp.zeros((spec.groups, spec.codebook_size, spec.group_dim), dtype),
    }


# ---------------------------------------------------------------------------
# Encode / decode
# ---------------------------------------------------------------------------


def _grouped(x: jax.Array, spec: VQSpec) -> jax.Array:
    """(..., D) -> (..., G, D/G)."""
    return x.reshape(*x.shape[:-1], spec.groups, spec.group_dim)


def _flat(xg: jax.Array) -> jax.Array:
    return xg.reshape(*xg.shape[:-2], -1)


def encode(params: Dict[str, jax.Array], x: jax.Array, spec: VQSpec) -> jax.Array:
    """Nearest-centroid codes.  x: (..., D) -> codes: (..., G) int32.

    Uses ||x-e||^2 = ||x||^2 - 2 x.e + ||e||^2; the ||x||^2 term is constant
    per row and dropped.  The 2x.e term is an MXU matmul — this is the
    compute hot-spot mirrored by the Pallas ``vq_assign`` kernel.
    """
    cb = params["codebook"].astype(jnp.float32)  # (G, K, dg)
    xg = _grouped(x, spec).astype(jnp.float32)  # (..., G, dg)
    # scores: (..., G, K)
    dots = jnp.einsum("...gd,gkd->...gk", xg, cb)
    e_sq = jnp.sum(cb * cb, axis=-1)  # (G, K)
    dist = e_sq - 2.0 * dots
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def decode(params: Dict[str, jax.Array], codes: jax.Array, spec: VQSpec) -> jax.Array:
    """codes: (..., G) int32 -> x_hat: (..., D)."""
    cb = params["codebook"]  # (G, K, dg)
    # take along the K axis per group
    g_idx = jnp.arange(spec.groups)
    xg = cb[g_idx, codes]  # (..., G, dg) via advanced indexing
    return _flat(xg).astype(cb.dtype)


def quantize_st(
    params: Dict[str, jax.Array], x: jax.Array, spec: VQSpec
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Straight-through quantization.

    Returns (x_hat_ste, codes, commit_loss_per_elt_sum) where
    x_hat_ste = x + sg(x_hat - x) so gradients flow to x, and
    commit = ||x - sg(x_hat)||^2 summed over all elements (caller scales by
    beta and averages as desired).
    """
    codes = encode(params, x, spec)
    x_hat = decode(params, codes, spec).astype(x.dtype)
    ste = x + jax.lax.stop_gradient(x_hat - x)
    commit = jnp.sum(jnp.square(x.astype(jnp.float32) - jax.lax.stop_gradient(x_hat).astype(jnp.float32)))
    return ste, codes, commit


# ---------------------------------------------------------------------------
# Code packing (beyond-paper wire-format optimisation)
# ---------------------------------------------------------------------------


def code_dtype(codebook_size: int):
    """Narrowest storage dtype holding log2(K)-bit codes — the single source
    of truth for code storage width (wire packing, vq slab caches, paged
    code pools, and the Appendix-G byte accounting all derive from it)."""
    if codebook_size <= 256:
        return jnp.uint8
    if codebook_size <= 65536:
        return jnp.uint16
    return jnp.int32


def pack_codes(codes: jax.Array, spec: VQSpec) -> jax.Array:
    """Narrow codes to the smallest dtype holding log2(K) bits before the
    all-gather.  int32 -> uint8 (K<=256) / uint16 (K<=65536)."""
    return codes.astype(code_dtype(spec.codebook_size))


def unpack_codes(packed: jax.Array) -> jax.Array:
    return packed.astype(jnp.int32)


# ---------------------------------------------------------------------------
# K-means init (paper: codebook initialised by k-means over pretrained
# intermediate embeddings)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("spec", "iters"))
def kmeans_init(
    key: jax.Array, samples: jax.Array, spec: VQSpec, iters: int = 10
) -> Dict[str, jax.Array]:
    """Lloyd's k-means per group over ``samples`` (N, D) -> codebook params."""
    n = samples.shape[0]
    xg = _grouped(samples, spec).astype(jnp.float32)  # (N, G, dg)
    xg = jnp.swapaxes(xg, 0, 1)  # (G, N, dg)
    k = spec.codebook_size
    idx = jax.random.choice(key, n, (k,), replace=n < k)
    cb0 = xg[:, idx, :]  # (G, K, dg)

    def step(cb, _):
        d = (
            jnp.sum(cb * cb, axis=-1)[:, None, :]
            - 2.0 * jnp.einsum("gnd,gkd->gnk", xg, cb)
        )  # (G, N, K)
        assign = jnp.argmin(d, axis=-1)  # (G, N)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (G, N, K)
        counts = jnp.sum(onehot, axis=1)  # (G, K)
        sums = jnp.einsum("gnk,gnd->gkd", onehot, xg)
        new = jnp.where(
            counts[..., None] > 0, sums / jnp.maximum(counts[..., None], 1.0), cb
        )
        return new, None

    cb, _ = jax.lax.scan(step, cb0, None, length=iters)
    return {"codebook": cb}


# ---------------------------------------------------------------------------
# EMA codebook update (paper: codebook updated via exponential moving average
# during fine-tuning, following VQ-VAE)
# ---------------------------------------------------------------------------


def ema_update(
    params: Dict[str, jax.Array],
    state: Dict[str, jax.Array],
    x: jax.Array,
    codes: jax.Array,
    spec: VQSpec,
    decay: float = 0.99,
    eps: float = 1e-5,
) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """One EMA step given a batch of vectors and their assigned codes."""
    xg = _grouped(x, spec).astype(jnp.float32).reshape(-1, spec.groups, spec.group_dim)
    cf = codes.reshape(-1, spec.groups)  # (N, G)
    onehot = jax.nn.one_hot(cf, spec.codebook_size, dtype=jnp.float32)  # (N, G, K)
    counts = jnp.sum(onehot, axis=0).astype(jnp.float32)  # (G, K)
    sums = jnp.einsum("ngk,ngd->gkd", onehot, xg)  # (G, K, dg)

    new_size = decay * state["cluster_size"] + (1 - decay) * counts
    new_sum = decay * state["cluster_sum"] + (1 - decay) * sums
    n = jnp.sum(new_size, axis=-1, keepdims=True)
    stable = (new_size + eps) / (n + spec.codebook_size * eps) * n
    new_cb = new_sum / stable[..., None]
    # keep dead codes where they were
    new_cb = jnp.where(new_size[..., None] > eps, new_cb, params["codebook"])
    return {"codebook": new_cb.astype(params["codebook"].dtype)}, {
        "cluster_size": new_size,
        "cluster_sum": new_sum,
    }


# ---------------------------------------------------------------------------
# Projected codebooks (TPU adaptation, DESIGN.md §2)
# ---------------------------------------------------------------------------


def project_codebook(params: Dict[str, jax.Array], w: jax.Array, spec: VQSpec) -> jax.Array:
    """Fold a linear projection into the codebook.

    decode(codes) @ W == sum_g Ep[g, codes[g], :] where
    Ep[g,k,:] = codebook[g,k,:] @ W[g*dg:(g+1)*dg, :].
    Lets receivers reconstruct *projected* K-hat/V-hat without materialising
    X-hat when T >> G*K.  Returns (G, K, out_dim).
    """
    dg = spec.group_dim
    wg = w.reshape(spec.groups, dg, -1)  # (G, dg, out)
    return jnp.einsum("gkd,gdo->gko", params["codebook"].astype(w.dtype), wg)


def decode_projected(proj_cb: jax.Array, codes: jax.Array, spec: VQSpec) -> jax.Array:
    """codes (..., G) + projected codebook (G, K, out) -> (..., out)."""
    g_idx = jnp.arange(spec.groups)
    picked = proj_cb[g_idx, codes]  # (..., G, out)
    return jnp.sum(picked, axis=-2)
