"""Analytic communication/latency model reproducing the paper's tables.

The paper evaluates ASTRA against TP (Megatron), SP (Voltage) and BP
(DeTransformer) under bandwidth caps of 10-500 Mbps on 2-8 devices.  Their
latency model is ``total = compute/N + transmitted_bits/bandwidth (+ link
latency per round)``; we reproduce the communication volumes exactly from the
method definitions and calibrate the compute term from measured (or supplied)
per-layer times.  All volumes are per device per forward pass.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict


@dataclasses.dataclass(frozen=True)
class CommEnv:
    bandwidth_mbps: float
    num_devices: int = 4
    seq_len: int = 1024
    d_model: int = 768
    num_layers: int = 12
    precision_bits: int = 32
    link_latency_s: float = 0.002  # per collective round (Wi-Fi RTT scale)


def _mbits(bits: float) -> float:
    return bits / 1e6


def comm_time_s(bits_per_device: float, env: CommEnv, rounds: int) -> float:
    return _mbits(bits_per_device) / env.bandwidth_mbps + rounds * env.link_latency_s


# -- per-method communication volumes (bits per device per forward pass) ----


def bits_tensor_parallel(env: CommEnv) -> float:
    """Megatron TP: 2 all-reduce per layer; ring all-reduce moves
    2*(N-1)/N * T * D * r bits per device per all-reduce."""
    per_ar = 2 * (env.num_devices - 1) / env.num_devices * env.seq_len * env.d_model * env.precision_bits
    return env.num_layers * 2 * per_ar


def bits_sequence_parallel(env: CommEnv) -> float:
    """Voltage SP: one all-gather of all non-local token embeddings/layer."""
    t_loc = env.seq_len / env.num_devices
    per_ag = (env.num_devices - 1) * t_loc * env.d_model * env.precision_bits
    return env.num_layers * per_ag


def bits_block_parallel(env: CommEnv, nb: int, variant: str = "AG") -> float:
    """DeTransformer BP: only ``nb`` block boundaries communicate."""
    t_loc = env.seq_len / env.num_devices
    if variant == "AG":
        per = (env.num_devices - 1) * t_loc * env.d_model * env.precision_bits
    else:  # BP+SP: sequence-parallel inside retained blocks: 2 exchanges
        per = 2 * (env.num_devices - 1) * t_loc * env.d_model * env.precision_bits
    return nb * per


def bits_astra(env: CommEnv, groups: int, codebook_size: int = 1024,
               codebooks_per_layer: int = 1) -> float:
    """ASTRA: all-gather of VQ codes only — G*log2(K) bits per non-local
    token per layer (×C codebooks)."""
    t_loc = env.seq_len / env.num_devices
    bits_tok = groups * math.log2(codebook_size) * codebooks_per_layer
    per = (env.num_devices - 1) * t_loc * bits_tok
    return env.num_layers * per


def astra_total_bits_per_token(num_layers: int, groups: int,
                               codebook_size: int = 1024,
                               codebooks_per_layer: int = 1) -> float:
    """Paper Tables 1/3/6: 'Total Bits per Token' = L * C * G * log2 K."""
    return num_layers * codebooks_per_layer * groups * math.log2(codebook_size)


def full_precision_bits_per_token(num_layers: int, d_model: int,
                                  precision_bits: int = 32,
                                  codebooks_per_layer: int = 1) -> float:
    """Baseline bits/token: L * C * D * r (C=1 for ViT/GPT2, 2 for Llama KV)."""
    return num_layers * codebooks_per_layer * d_model * precision_bits


def compression_ratio(num_layers: int, d_model: int, groups: int,
                      codebook_size: int = 1024, precision_bits: int = 32,
                      codebooks_per_layer: int = 1) -> float:
    """Paper Tables 1/3/6.  The full-precision baseline transmits the block
    activations once (C=1) regardless of how many codebooks ASTRA uses, so
    Table 6's Llama-3 ratio is L*D*r / (L*2*G*log2 K) = 1638.4 at G=1."""
    return full_precision_bits_per_token(
        num_layers, d_model, precision_bits, 1
    ) / astra_total_bits_per_token(
        num_layers, groups, codebook_size, codebooks_per_layer
    )


# -- disaggregated prefill/decode: KV-cache migration -------------------------


def migration_time_s(num_bytes: float, bandwidth_mbps: float, *,
                     link_latency_s: float = 0.002) -> float:
    """One prefill -> decode cache hand-off over a ``bandwidth_mbps`` link:
    a single point-to-point transfer, one round of link latency."""
    return (num_bytes * 8.0) / (bandwidth_mbps * 1e6) + link_latency_s


def migration_report(fp_bytes: float, coded_bytes: float,
                     bandwidths_mbps=(10.0, 100.0, 500.0)) -> Dict:
    """Hand-off cost table for the disaggregated engines: the measured
    coded (VQ) migration against the full-precision cache the same
    requests would have shipped, at the paper's bandwidth grid."""
    fp_bytes = float(fp_bytes)
    coded_bytes = float(coded_bytes)
    return {
        "fp_bytes": fp_bytes,
        "coded_bytes": coded_bytes,
        "compression": fp_bytes / max(coded_bytes, 1.0),
        "transfer_s": {
            f"{bw:g}": {"fp": migration_time_s(fp_bytes, bw),
                        "coded": migration_time_s(coded_bytes, bw)}
            for bw in bandwidths_mbps
        },
    }


# -- end-to-end latency model ------------------------------------------------


def latency_model(
    env: CommEnv,
    single_device_compute_s: float,
    method: str,
    *,
    groups: int = 1,
    nb: int = 1,
    astra_overhead_frac: float = 0.12,
) -> float:
    """End-to-end latency (s).  ``single_device_compute_s`` is the measured
    single-device forward time; parallel compute = that / N (+ ASTRA's VQ
    encode/decode overhead fraction, measured at ~12% in our CPU benches)."""
    n = env.num_devices
    comp = single_device_compute_s / n
    if method == "single":
        return single_device_compute_s
    if method == "TP":
        return comp + comm_time_s(bits_tensor_parallel(env), env, 2 * env.num_layers)
    if method == "SP":
        return comp + comm_time_s(bits_sequence_parallel(env), env, env.num_layers)
    if method == "BP+AG":
        return comp + comm_time_s(bits_block_parallel(env, nb, "AG"), env, nb)
    if method == "BP+SP":
        return comp + comm_time_s(bits_block_parallel(env, nb, "SP"), env, 2 * nb)
    if method == "ASTRA":
        comp = comp * (1.0 + astra_overhead_frac)
        return comp + comm_time_s(bits_astra(env, groups), env, env.num_layers)
    raise ValueError(method)


def speedup_table(env_grid, single_device_compute_s: float, methods) -> Dict:
    out = {}
    for env in env_grid:
        row = {}
        for m, kw in methods.items():
            lat = latency_model(env, single_device_compute_s, m.split("@")[0], **kw)
            row[m] = single_device_compute_s / lat
        out[env.bandwidth_mbps] = row
    return out
