"""Byte-level tokenizer (vocab = 256 bytes + specials)."""
from __future__ import annotations

from typing import List

PAD, BOS, EOS = 256, 257, 258
VOCAB_SIZE = 259


class ByteTokenizer:
    pad_id, bos_id, eos_id = PAD, BOS, EOS
    vocab_size = VOCAB_SIZE

    def encode(self, text: str, add_bos: bool = True,
               add_eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [BOS] + ids
        if add_eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids) -> str:
        b = bytes(i for i in ids if i < 256)
        return b.decode("utf-8", errors="replace")
