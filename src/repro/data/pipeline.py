"""Data pipeline: synthetic corpora + LM batch iterator with host sharding.

The synthetic corpus is a 2nd-order Markov byte stream (learnable structure,
so training-loss-decreases tests are meaningful) with optional repeated
"phrases" to give attention something long-range to exploit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.tokenizer import ByteTokenizer

_PHRASES = [
    b"the transformer model computes attention over all tokens ",
    b"vector quantization maps embeddings to discrete codes ",
    b"multi-device inference reduces latency under bandwidth limits ",
    b"sequence parallelism partitions input tokens across devices ",
    b"noise augmented quantization improves generalization ",
]


def synthetic_corpus(num_bytes: int, seed: int = 0) -> np.ndarray:
    """Markov-ish byte stream built from repeated phrases + noise."""
    rng = np.random.default_rng(seed)
    chunks, total = [], 0
    while total < num_bytes:
        p = _PHRASES[rng.integers(len(_PHRASES))]
        if rng.random() < 0.15:  # typo noise
            p = bytes(b if rng.random() > 0.03 else int(rng.integers(97, 123))
                      for b in p)
        chunks.append(np.frombuffer(p, dtype=np.uint8))
        total += len(p)
    return np.concatenate(chunks)[:num_bytes].astype(np.int32)


@dataclasses.dataclass
class LMDataConfig:
    seq_len: int = 256
    batch_size: int = 8
    corpus_bytes: int = 1 << 20
    seed: int = 0


def lm_batches(cfg: LMDataConfig, *, num_shards: int = 1, shard: int = 0
               ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {tokens, labels} next-token batches.

    ``num_shards``/``shard`` give per-host data parallelism (each host reads
    a disjoint slice of the batch dim).
    """
    corpus = synthetic_corpus(cfg.corpus_bytes, cfg.seed)
    rng = np.random.default_rng(cfg.seed + 17 + shard)
    n = len(corpus) - cfg.seq_len - 1
    local_bs = cfg.batch_size // num_shards
    while True:
        starts = rng.integers(0, n, size=local_bs)
        toks = np.stack([corpus[s: s + cfg.seq_len] for s in starts])
        labels = np.stack([corpus[s + 1: s + cfg.seq_len + 1] for s in starts])
        yield {"tokens": toks.astype(np.int32),
               "labels": labels.astype(np.int32)}


def classification_batches(batch_size: int, num_patches: int, feat_dim: int,
                           num_classes: int, seed: int = 0
                           ) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic ViT-style classification: class-dependent patch means so a
    model can actually learn the mapping."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(num_classes, feat_dim)).astype(np.float32)
    while True:
        y = rng.integers(0, num_classes, size=batch_size)
        base = protos[y][:, None, :]  # (B, 1, F)
        x = base + 0.5 * rng.normal(size=(batch_size, num_patches, feat_dim))
        yield {"patch_embeds": x.astype(np.float32),
               "labels": y.astype(np.int32)}


def seq2seq_batches(batch_size: int, src_len: int, tgt_len: int,
                    feat_dim: int, vocab: int, seed: int = 0
                    ) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic enc-dec data: frame embeddings + target byte stream."""
    tok = ByteTokenizer()
    rng = np.random.default_rng(seed)
    corpus = synthetic_corpus(1 << 18, seed)
    n = len(corpus) - tgt_len - 1
    while True:
        starts = rng.integers(0, n, size=batch_size)
        tgt = np.stack([corpus[s: s + tgt_len] for s in starts])
        lab = np.stack([corpus[s + 1: s + tgt_len + 1] for s in starts])
        frames = rng.normal(size=(batch_size, src_len, feat_dim))
        yield {"frame_embeds": frames.astype(np.float32),
               "tokens": tgt.astype(np.int32) % vocab,
               "labels": lab.astype(np.int32) % vocab}
