"""repro.analysis — repo-native static analysis & compiled-artifact lint.

Source rules (``source.py``) enforce the serving stack's structural
invariants; HLO auditors (``hlo.py`` + ``trace_audit.py``) lint what the
compiler actually built.  One CLI runs both:
``python -m repro.analysis.lint [--strict] [--rule ID] [--json PATH]
[--trace]``.  This module imports only the stdlib pieces; the trace
audit (which needs jax) loads lazily behind ``--trace``.
"""
from repro.analysis.rules import (  # noqa: F401
    ALLOW_RULE,
    Finding,
    REGISTRY,
    Rule,
    SRC_ROOT,
    get_rules,
    register,
    run_rules,
)
from repro.analysis import source as _source  # noqa: F401  (registers rules)
