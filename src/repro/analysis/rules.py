"""Rule framework for the repo-native static analysis (`repro.analysis`).

The serving stack's correctness rests on invariants no generic linter
knows about — no ``cache_mode`` string dispatch outside the backend
module, no version-sensitive jax APIs outside ``compat.py``, no
``interpret=True`` shipped to the TPU hot path, no host syncs inside the
jitted serving modules.  Each invariant is a named :class:`Rule` in one
registry; :func:`run_rules` walks a source tree, runs every (selected)
rule against every file and returns structured :class:`Finding` records.
The same registry backs the ``python -m repro.analysis.lint`` CLI, the
tier-1 pytest wrapper (``tests/test_analysis.py``) and the CI lint lane.

Allowlist policy
----------------
A finding may be suppressed at the offending line with an inline marker
carrying a mandatory reason::

    stats = jax.device_get(stats)  # lint: allow[host-sync] host boundary

or, for long lines, on a comment-only line immediately above::

    # lint: allow[host-sync] host boundary fetch, runs outside jit
    stats = jax.device_get(stats)

A marker without a reason does NOT suppress anything and is itself
reported (rule ``lint-allow``) — the escape hatch must document why.
Structural exemptions (e.g. ``compat.py`` may use the raw jax APIs it
wraps) live on the rule itself via ``only``/``exclude`` path globs, so
the sanctioned home of each pattern is part of the rule's definition.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import pathlib
from typing import Callable, Dict, Iterable, List, Optional, Sequence

# the default scan root: src/repro (this package's parent)
SRC_ROOT = pathlib.Path(__file__).resolve().parents[1]

# rule id reserved for malformed/unknown allow markers
ALLOW_RULE = "lint-allow"


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation: ``path:line: [rule] message``."""

    path: str  # posix path relative to the scanned root
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    """A named invariant checked per source file.

    ``check`` receives a :class:`repro.analysis.source.SourceFile` and
    yields findings; ``only`` / ``exclude`` are fnmatch globs over the
    root-relative posix path — ``only=()`` means every file, and an
    ``exclude`` match wins (that's where the pattern legitimately lives).
    """

    id: str
    description: str
    check: Callable[["object"], Iterable[Finding]]
    only: Sequence[str] = ()
    exclude: Sequence[str] = ()

    def applies_to(self, rel: str) -> bool:
        if self.only and not any(fnmatch.fnmatch(rel, g) for g in self.only):
            return False
        return not any(fnmatch.fnmatch(rel, g) for g in self.exclude)


REGISTRY: Dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    if rule.id in REGISTRY:
        raise ValueError(f"duplicate rule id {rule.id!r}")
    REGISTRY[rule.id] = rule
    return rule


def get_rules(ids: Optional[Sequence[str]] = None) -> List[Rule]:
    """Selected rules (all registered when ``ids`` is None), order-stable."""
    if ids is None:
        return list(REGISTRY.values())
    missing = [i for i in ids if i not in REGISTRY]
    if missing:
        known = ", ".join(sorted(REGISTRY))
        raise KeyError(f"unknown rule id(s) {missing} (known: {known})")
    return [REGISTRY[i] for i in ids]


def run_rules(root: Optional[pathlib.Path] = None, *,
              rules: Optional[Sequence[str]] = None,
              files: Optional[Sequence[pathlib.Path]] = None
              ) -> List[Finding]:
    """Run the (selected) source rules over every ``*.py`` under ``root``.

    Returns all findings sorted by path/line.  Inline ``lint: allow``
    markers suppress same-rule findings on their line; malformed markers
    (no reason / unknown rule id) surface as ``lint-allow`` findings so a
    broken suppression can never silently pass.
    """
    from repro.analysis.source import SourceFile  # cycle-free at call time

    root = pathlib.Path(root) if root is not None else SRC_ROOT
    selected = get_rules(rules)
    findings: List[Finding] = []
    for path in sorted(files) if files is not None else sorted(root.rglob("*.py")):
        sf = SourceFile(pathlib.Path(path), root)
        findings.extend(sf.meta_findings)
        for rule in selected:
            if not rule.applies_to(sf.rel):
                continue
            for f in rule.check(sf):
                if rule.id not in sf.allows.get(f.line, set()):
                    findings.append(f)
    return sorted(findings)
