"""CLI for the repo-native static analysis.

Usage::

  python -m repro.analysis.lint                  # report, exit 0
  python -m repro.analysis.lint --strict         # exit 1 on any finding
  python -m repro.analysis.lint --rule host-sync --rule bare-jit
  python -m repro.analysis.lint --json report.json   # ('-' for stdout)
  python -m repro.analysis.lint --root tests/fixtures/analysis/bad_tree
  python -m repro.analysis.lint --trace          # + compiled-artifact audit
  python -m repro.analysis.lint --list-rules

The default run is source-rules only — stdlib imports, no jax — so the
CI lint lane finishes in seconds.  ``--trace`` additionally compiles the
jitted serving steps for a small (cache_mode, use_pallas) matrix and
lints the optimized HLO + kernel-engagement counters (slow; needs jax).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List, Optional

from repro.analysis.rules import REGISTRY, SRC_ROOT, Finding, run_rules


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-native static analysis: source rules + "
                    "compiled-artifact audits")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on any finding")
    ap.add_argument("--rule", action="append", default=None,
                    metavar="ID", help="run only this rule (repeatable)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a JSON report to PATH ('-' for stdout)")
    ap.add_argument("--root", default=None, metavar="DIR",
                    help=f"source tree to scan (default: {SRC_ROOT})")
    ap.add_argument("--trace", action="store_true",
                    help="also lower+audit the jitted serving steps "
                         "(slow; imports jax)")
    ap.add_argument("--list-rules", action="store_true",
                    help="list registered rules and exit")
    args = ap.parse_args(argv)

    # importing the module registers the built-in rules
    import repro.analysis.source  # noqa: F401

    if args.list_rules:
        for rid in sorted(REGISTRY):
            print(f"{rid:24s} {REGISTRY[rid].description}")
        return 0

    root = pathlib.Path(args.root) if args.root else SRC_ROOT
    findings: List[Finding] = run_rules(root, rules=args.rule)

    reports: List[dict] = []
    if args.trace:
        from repro.analysis.trace_audit import audit_matrix

        trace_findings, reports = audit_matrix()
        findings.extend(trace_findings)

    for f in findings:
        print(f)
    n_rules = len(args.rule) if args.rule else len(REGISTRY)
    summary = (f"repro.analysis.lint: {len(findings)} finding(s) "
               f"({n_rules} rule(s) over {root})")
    print(summary if findings else
          f"repro.analysis.lint: clean ({n_rules} rule(s) over {root})")

    if args.json:
        payload = {
            "root": str(root),
            "rules": sorted(args.rule) if args.rule else sorted(REGISTRY),
            "strict": bool(args.strict),
            "findings": [f.to_dict() for f in findings],
        }
        if reports:
            payload["trace_reports"] = reports
        text = json.dumps(payload, indent=1)
        if args.json == "-":
            print(text)
        else:
            pathlib.Path(args.json).write_text(text + "\n")

    return 1 if (args.strict and findings) else 0


if __name__ == "__main__":
    sys.exit(main())
