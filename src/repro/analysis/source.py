"""Tokenize/AST source model + the built-in source rules.

:class:`SourceFile` is the per-file view every rule checks against: the
token stream with comments and string literals stripped (so docstrings
and prose can't trip a rule — the same trick the old copy-pasted
``_code_only`` helpers in ``tests/test_compat.py`` and
``tests/test_cache_backend.py`` used), re-joined into one searchable
string with an offset→line map so findings carry real line numbers, plus
the parsed AST for rules that need structure (e.g. ``float(traced)``).

The rules registered here (see each ``register`` call):

``compat-api``
    Version-sensitive jax APIs outside ``compat.py`` — the PR-1
    invariant that keeps the pinned-jax migration in one file.
``cache-mode-dispatch``
    ``cache_mode`` string comparisons outside ``serving/cache_backend.py``
    — layouts are backends behind one protocol, not scattered branches.
``interpret-literal``
    ``interpret=True`` literals outside ``kernels/ops.py`` — the single
    platform gate (``resolve_interpret``) decides interpret vs compiled;
    a literal ``True`` ships the Pallas interpreter to the TPU hot path.
``pallas-call``
    ``pl.pallas_call`` outside ``kernels/`` — kernels are wrapped once,
    with invocation counters, oracles and geometry gates; ad-hoc call
    sites bypass all three.
``host-sync``
    ``.item()`` / ``float(non-literal)`` / ``np.asarray`` /
    ``jax.device_get`` inside the jitted serving modules
    (``serving/steps.py``, ``serving/cache_backend.py``, ``kernels/``) —
    a blocking device→host transfer inside the hot path serializes the
    decode loop the whole PR-1 chunked-decode design exists to avoid.
``bare-jit``
    ``jax.jit`` in ``serving/`` outside ``steps.py`` — serving steps go
    through ``CountingJit`` so retraces stay observable and cache
    donation is applied uniformly.
``allocator-internals``
    ``._free`` / ``._owned`` / ``._refs`` access outside
    ``serving/kv_cache.py`` — the page allocator refcounts shared pages
    (prefix caching), so external mutation of its internals corrupts
    refcounts silently; everyone else uses the public
    ``alloc``/``share``/``release`` surface.
``cache-length-mutation``
    ``.block_table`` / ``._granted`` access outside the cache layer
    (``serving/kv_cache.py`` + ``serving/cache_backend.py``) — rollback
    (speculative decoding, preemption) must retreat the per-slot grant
    high-water, the block-table rows and the page refcounts *together*;
    a direct poke desyncs them.  Engines use
    ``advance``/``rollback``/``release``/``tables``.
``swap-arena-internals``
    ``._swapped`` access outside ``serving/kv_cache.py`` — the preemption
    swap arena keys host-side payloads by request uid and keeps its
    traffic counters consistent through ``stash``/``peek``/``pop``; a
    direct poke at the backing dict leaks resident bytes or double-frees
    a restore.  Schedulers use ``holds``/``stash``/``peek``/``pop``/
    ``stats``.
"""
from __future__ import annotations

import ast
import bisect
import io
import pathlib
import re
import tokenize
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.rules import (
    ALLOW_RULE,
    Finding,
    REGISTRY,
    Rule,
    register,
)

# inline suppression marker (see module docstring; reason required)
_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\[([A-Za-z0-9_-]+)\]\s*(.*)")

# token types that never count as code
_NON_CODE = (tokenize.COMMENT, tokenize.STRING, tokenize.NEWLINE,
             tokenize.NL, tokenize.INDENT, tokenize.DEDENT)


class SourceFile:
    """One python file, tokenized once and shared by every rule."""

    def __init__(self, path: pathlib.Path, root: pathlib.Path):
        self.path = path
        try:
            self.rel = path.resolve().relative_to(
                pathlib.Path(root).resolve()).as_posix()
        except ValueError:
            self.rel = path.as_posix()
        self.text = path.read_text(encoding="utf-8")
        self.allows: Dict[int, Set[str]] = {}
        self.meta_findings: List[Finding] = []
        self._tree: Optional[ast.AST] = None
        self._tree_parsed = False

        pieces: List[str] = []
        lines: List[int] = []
        code_lines: Set[int] = set()
        comments: List[tokenize.TokenInfo] = []
        try:
            for tok in tokenize.generate_tokens(
                    io.StringIO(self.text).readline):
                if tok.type == tokenize.COMMENT:
                    comments.append(tok)
                    continue
                if tok.type in _NON_CODE or not tok.string.strip():
                    continue
                pieces.append(tok.string)
                lines.append(tok.start[0])
                code_lines.add(tok.start[0])
        except (tokenize.TokenError, IndentationError, SyntaxError) as e:
            self.meta_findings.append(Finding(
                self.rel, 1, "parse-error", f"file does not tokenize: {e}"))

        self._lines = lines
        self._offsets: List[int] = []
        off = 0
        for p in pieces:
            self._offsets.append(off)
            off += len(p) + 1
        self.code = " ".join(pieces)

        for tok in comments:
            m = _ALLOW_RE.search(tok.string)
            if not m:
                continue
            rule_id, reason = m.group(1), m.group(2).strip()
            # a marker on a comment-only line covers the next line
            line = tok.start[0]
            target = line if line in code_lines else line + 1
            if not reason:
                self.meta_findings.append(Finding(
                    self.rel, line, ALLOW_RULE,
                    f"allow[{rule_id}] marker has no reason — the escape "
                    f"hatch must say why (finding NOT suppressed)"))
                continue
            if rule_id not in REGISTRY:
                self.meta_findings.append(Finding(
                    self.rel, line, ALLOW_RULE,
                    f"allow[{rule_id}] names an unknown rule "
                    f"(known: {', '.join(sorted(REGISTRY))})"))
                continue
            self.allows.setdefault(target, set()).add(rule_id)

    def line_at(self, offset: int) -> int:
        """Source line of a character offset into :attr:`code`."""
        i = bisect.bisect_right(self._offsets, offset) - 1
        return self._lines[i] if 0 <= i < len(self._lines) else 1

    def finditer(self, pattern: "re.Pattern") -> Iterator[Tuple["re.Match", int]]:
        for m in pattern.finditer(self.code):
            yield m, self.line_at(m.start())

    @property
    def tree(self) -> Optional[ast.AST]:
        if not self._tree_parsed:
            self._tree_parsed = True
            try:
                self._tree = ast.parse(self.text)
            except SyntaxError:
                self._tree = None
        return self._tree


def _regex_rule(rid: str, description: str, patterns, message: str, *,
                only=(), exclude=()) -> Rule:
    compiled = [re.compile(p) for p in patterns]

    def check(sf: SourceFile):
        seen = set()
        for pat in compiled:
            for m, line in sf.finditer(pat):
                key = (line, m.group(0))
                if key in seen:
                    continue
                seen.add(key)
                yield Finding(sf.rel, line, rid,
                              f"{message} (matched {m.group(0)!r})")

    return register(Rule(rid, description, check, only=only, exclude=exclude))


# ---------------------------------------------------------------------------
# compat-api — ported from tests/test_compat.py's FORBIDDEN list
# ---------------------------------------------------------------------------

_regex_rule(
    "compat-api",
    "version-sensitive jax APIs must route through repro/compat.py",
    [
        r"jax\s*\.\s*shard_map",
        r"experimental\s*\.\s*shard_map",
        r"jax\s*\.\s*sharding\s*\.\s*AxisType",
        # the compat accessor itself (`compat.cost_analysis(...)`) is fine
        r"(?<!compat )\.\s*cost_analysis\s*\(",
        r"jax\s*\.\s*lax\s*\.\s*axis_size",
    ],
    "version-sensitive JAX API used directly — route through repro/compat.py",
    exclude=("compat.py",),
)


# ---------------------------------------------------------------------------
# cache-mode-dispatch — ported from tests/test_cache_backend.py
# ---------------------------------------------------------------------------

_regex_rule(
    "cache-mode-dispatch",
    "cache_mode string dispatch lives only in serving/cache_backend.py",
    [
        r"cache_mode\s*==",
        r"==\s*cache_mode",
        r"cache_mode\s*!=",
        r"!=\s*cache_mode",
        r"cache_mode\s+not\s+in\s",
        r"cache_mode\s+in\s",
    ],
    "cache_mode string dispatch outside serving/cache_backend.py — add a "
    "CacheBackend hook instead",
    exclude=("serving/cache_backend.py",),
)


# ---------------------------------------------------------------------------
# interpret-literal — the resolve_interpret platform-gate invariant
# ---------------------------------------------------------------------------

_regex_rule(
    "interpret-literal",
    "no interpret=True literals outside kernels/ops.py",
    # also catches annotated defaults (`interpret: bool = True`)
    [r"interpret\s*(?::\s*[\w\.\[\], ]+?\s*)?=\s*True"],
    "interpret=True pins the Pallas interpreter unconditionally — pass "
    "interpret=None and let kernels.ops.resolve_interpret platform-gate it",
    exclude=("kernels/ops.py",),
)


# ---------------------------------------------------------------------------
# pallas-call — raw pallas_call sites stay inside kernels/
# ---------------------------------------------------------------------------

_regex_rule(
    "pallas-call",
    "direct pl.pallas_call only inside kernels/",
    [r"\bpallas_call\s*\("],
    "raw pallas_call outside kernels/ — wrap it as a kernels entry point "
    "(invocation counter + ref.py oracle + interpret gate)",
    exclude=("kernels/*",),
)


# ---------------------------------------------------------------------------
# host-sync — no blocking device->host transfers in the jitted modules
# ---------------------------------------------------------------------------

_HOST_SYNC_MODULES = ("serving/steps.py", "serving/cache_backend.py",
                      "kernels/*")

_HOST_SYNC_PATTERNS = [re.compile(p) for p in (
    r"\.\s*item\s*\(",
    r"jax\s*\.\s*device_get\b",
    r"\bnp\s*\.\s*asarray\s*\(",
    r"\bnumpy\s*\.\s*asarray\s*\(",
)]


def _check_host_sync(sf: SourceFile):
    for pat in _HOST_SYNC_PATTERNS:
        for m, line in sf.finditer(pat):
            yield Finding(
                sf.rel, line, "host-sync",
                f"host-sync hazard in a jitted serving module (matched "
                f"{m.group(0).strip()!r}) — this blocks on device->host "
                f"transfer when the value is traced")
    tree = sf.tree
    if tree is None:
        return
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "float" and node.args
                and not isinstance(node.args[0], ast.Constant)):
            yield Finding(
                sf.rel, node.lineno, "host-sync",
                "float(<non-literal>) in a jitted serving module — on a "
                "traced value this is a blocking device->host sync (use "
                "jnp ops, or allowlist with a reason if provably static)")


register(Rule(
    "host-sync",
    "no host-sync hazards (.item / float(traced) / np.asarray / "
    "jax.device_get) inside the jitted serving modules",
    _check_host_sync,
    only=_HOST_SYNC_MODULES,
))


# ---------------------------------------------------------------------------
# bare-jit — serving steps compile through CountingJit, not raw jax.jit
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# allocator-internals — PageAllocator state is private to kv_cache.py
# ---------------------------------------------------------------------------

_regex_rule(
    "allocator-internals",
    "PageAllocator internals (._free/._owned/._refs) stay inside "
    "serving/kv_cache.py",
    [r"\.\s*_free\b", r"\.\s*_owned\b", r"\.\s*_refs\b"],
    "PageAllocator internal state accessed outside serving/kv_cache.py — "
    "pages are refcounted (prefix sharing), so external mutation corrupts "
    "the free list silently; use alloc/share/release/check_invariants",
    exclude=("serving/kv_cache.py",),
)


# ---------------------------------------------------------------------------
# cache-length-mutation — KV grant/table bookkeeping stays in kv_cache.py
# ---------------------------------------------------------------------------

_regex_rule(
    "cache-length-mutation",
    "KV cache length/table bookkeeping (.block_table/._granted) stays "
    "inside serving/kv_cache.py + serving/cache_backend.py",
    [r"\.\s*block_table\b", r"\.\s*_granted\b"],
    "cache grant state poked outside the cache layer — rollback "
    "(speculative decoding, preemption) retreats the per-slot token "
    "high-water and block-table rows together; a direct poke desyncs them "
    "from the page refcounts.  Use advance/rollback/release/tables",
    exclude=("serving/kv_cache.py", "serving/cache_backend.py"),
)


# ---------------------------------------------------------------------------
# swap-arena-internals — preemption swap payloads stay behind the arena API
# ---------------------------------------------------------------------------

_regex_rule(
    "swap-arena-internals",
    "SwapArena internals (._swapped) stay inside serving/kv_cache.py",
    [r"\.\s*_swapped\b"],
    "swap-arena internal state accessed outside serving/kv_cache.py — "
    "entries are keyed by request uid and the swap_ins/bytes_in counters "
    "move with them; poking the dict directly leaks resident bytes or "
    "double-restores a victim.  Use holds/stash/peek/pop/stats",
    exclude=("serving/kv_cache.py",),
)


_regex_rule(
    "bare-jit",
    "serving/ compiles through CountingJit (steps.py), not bare jax.jit",
    # call, decorator and functools.partial forms alike
    [r"jax\s*\.\s*jit\b"],
    "bare jax.jit in serving/ bypasses CountingJit's retrace accounting "
    "and the donation conventions — build the step via serving.steps",
    only=("serving/*",),
    exclude=("serving/steps.py",),
)
