"""Compiled-artifact lint: collective / aliasing audits over optimized HLO.

This is the reusable home of what ``launch/dryrun.py`` used to do with
private regexes: scan a compiled executable's HLO text for oversized
collectives (the decode-step guard against involuntary rematerialization
of a sharded table — the gather shows up as a table-sized all-gather)
and check that donated buffers were actually aliased
(``input_output_alias`` annotations on the module header).  Pure string
parsing, no jax import — the CI lint lane can audit saved HLO dumps
without an accelerator stack.

Findings reuse :class:`repro.analysis.rules.Finding`; ``path`` carries
the caller's label (e.g. ``decode_chunk[fp]``) and ``line`` the HLO text
line of the offending instruction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.rules import Finding

# result-shape element sizes (bytes); mirrors roofline/analysis.py without
# importing it (that module is jax-adjacent, this one must stay stdlib-only)
DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


@dataclasses.dataclass(frozen=True)
class Collective:
    """One collective instruction: op kind, result bytes, HLO text line.

    ``bytes`` is the largest single shape in the result segment — tuple
    results of ``-start`` ops repeat the aliased operand, so a sum would
    double-count the payload."""

    op: str
    bytes: int
    line: int
    text: str


def _result_bytes(seg: str) -> int:
    """Largest shape in a result segment, in bytes."""
    biggest = 0
    for dt, dims in _SHAPE_RE.findall(seg):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        biggest = max(biggest, n * DTYPE_BYTES[dt])
    return biggest


def _collective_call_re(op: str) -> "re.Pattern":
    # HLO reads `%all-gather.5 = bf16[...]{...} all-gather(...)` — the op
    # name on the left also contains the op string, so the result shapes
    # are what sits between the `=` and the *call* (token followed by `(`).
    return re.compile(r"=\s*(.*?)\s*" + re.escape(op)
                      + r"(?:-start|-done)?\(", re.S)


def find_collectives(hlo: str,
                     ops: Sequence[str] = COLLECTIVE_OPS) -> List[Collective]:
    """Every collective call in the HLO with its result-shape bytes."""
    pats = [(op, _collective_call_re(op)) for op in ops]
    out: List[Collective] = []
    for lineno, line in enumerate(hlo.splitlines(), 1):
        for op, pat in pats:
            m = pat.search(line)
            if m:
                out.append(Collective(op, _result_bytes(m.group(1)), lineno,
                                      line.strip()))
    return out


def largest_allgather_bytes(hlo: str) -> int:
    """Max result size of any all-gather in the optimized HLO — the
    decode-step guard ``launch/dryrun.py`` records as
    ``largest_allgather_bytes``."""
    return largest_collective_bytes(hlo, "all-gather")


def largest_collective_bytes(hlo: str, op: str = "all-gather") -> int:
    return max((c.bytes for c in find_collectives(hlo, (op,))), default=0)


# module-header annotation: input_output_alias={ {0}: (2, {}, may-alias) }
_ALIAS_ENTRY_RE = re.compile(r"\{([0-9,\s]*)\}:\s*\((\d+)")


def input_output_aliases(hlo: str) -> List[Tuple[Tuple[int, ...], int]]:
    """Parsed ``input_output_alias`` entries:
    ``(output_tuple_index_path, parameter_number)`` per aliased buffer.
    Empty when the module carries no donation/aliasing."""
    # the annotation nests braces ({output index}: (...)), so take
    # everything from `input_output_alias={` to the matching close brace
    start = hlo.find("input_output_alias={")
    if start < 0:
        return []
    i = start + len("input_output_alias={")
    depth = 1
    j = i
    while j < len(hlo) and depth:
        if hlo[j] == "{":
            depth += 1
        elif hlo[j] == "}":
            depth -= 1
        j += 1
    block = hlo[i:j - 1]
    out = []
    for m in _ALIAS_ENTRY_RE.finditer(block):
        path = tuple(int(x) for x in m.group(1).split(",") if x.strip())
        out.append((path, int(m.group(2))))
    return out


def aliased_parameter_numbers(hlo: str) -> List[int]:
    return sorted({p for _, p in input_output_aliases(hlo)})


def audit_hlo(hlo: str, *, label: str,
              max_allgather_bytes: Optional[int] = None,
              max_collective_bytes: Optional[Dict[str, int]] = None,
              expect_alias_params: Sequence[int] = ()) -> List[Finding]:
    """Lint one compiled module's HLO text.

    * ``max_allgather_bytes`` — any all-gather with a result at or above
      this many bytes is a finding (``hlo-big-allgather``): the classic
      symptom of a sharded table being involuntarily rematerialized.
    * ``max_collective_bytes`` — the same cap per arbitrary collective op
      (``hlo-big-collective``).
    * ``expect_alias_params`` — parameter numbers the caller donated;
      each one missing from ``input_output_alias`` is a finding
      (``hlo-missing-alias``): the donation was requested but XLA copied.
    """
    findings: List[Finding] = []
    caps: Dict[str, int] = dict(max_collective_bytes or {})
    if max_allgather_bytes is not None:
        caps["all-gather"] = max_allgather_bytes
    if caps:
        for c in find_collectives(hlo, tuple(caps)):
            cap = caps[c.op]
            if c.bytes >= cap:
                rule = ("hlo-big-allgather" if c.op == "all-gather"
                        else "hlo-big-collective")
                findings.append(Finding(
                    label, c.line, rule,
                    f"{c.op} moves {c.bytes} bytes (cap {cap}) — a "
                    f"table/embed-sized collective in this step means a "
                    f"sharded buffer is being rematerialized"))
    if expect_alias_params:
        aliased = set(aliased_parameter_numbers(hlo))
        for p in expect_alias_params:
            if p not in aliased:
                findings.append(Finding(
                    label, 1, "hlo-missing-alias",
                    f"donated parameter {p} has no input_output_alias "
                    f"entry — XLA is copying the buffer, not updating "
                    f"in place"))
    return findings
