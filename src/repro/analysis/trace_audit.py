"""Compiled-artifact audit of the jitted serving steps.

Where ``source.py`` lints what the code *says*, this module lints what
the compiler actually *built*: for a (cache_mode, use_pallas) matrix it
constructs a reduced serving engine, lowers the jitted ``decode_chunk``
and ``prefill_chunk`` through ``CountingJit.lower``, and audits

* the optimized HLO via :mod:`repro.analysis.hlo` — no embed/table-sized
  all-gather in the decode step (the dryrun invariant, now shared), and
  ``input_output_alias`` entries present whenever the step was built
  with donated cache buffers on a platform that aliases;
* kernel engagement via ``kernels.ops.KERNEL_INVOCATIONS`` deltas — with
  ``use_pallas=True`` the Pallas wrappers must have traced (a silent
  jnp fallback passes every parity test while shipping the slow path),
  and with ``use_pallas=False`` they must NOT have.

Heavier than the source rules (it compiles real steps), so the CLI runs
it only under ``--trace`` and the pytest wrapper keeps the matrix small.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis import hlo as hlo_lint
from repro.analysis.rules import Finding

# (cache_mode, use_pallas[, seq_sharded]) combos the CLI audits under
# --trace; the seq-sharded rows lower the mesh decode + chunked-prefill
# steps (shard_map over every host device) through the same auditors
DEFAULT_MATRIX: Tuple[Tuple, ...] = (
    ("fp", False),
    ("fp", True),
    ("fp", False, True),
    ("fp", True, True),
    ("vq", True, True),
)

_MODELS: Dict[Tuple[str, bool], tuple] = {}


def _small_model(arch: str, astra: bool):
    """Reduced config + params, cached per (arch, astra) — vq layouts need
    the astra codebooks in the param tree."""
    key = (arch, astra)
    if key not in _MODELS:
        import dataclasses as dc

        import jax

        from repro.configs import get_config
        from repro.models import model_factory as mf

        cfg = get_config(arch).reduced()
        if not astra:
            cfg = dc.replace(cfg, astra=dc.replace(cfg.astra, enabled=False))
        params = mf.init_params(jax.random.PRNGKey(0), cfg)
        _MODELS[key] = (cfg, params)
    return _MODELS[key]


@dataclasses.dataclass
class StepAudit:
    """One audited compiled step: label + HLO stats + findings."""

    label: str
    hlo_lines: int
    largest_allgather_bytes: int
    num_collectives: int
    alias_entries: int
    donated: bool
    findings: List[Finding]

    def report(self) -> dict:
        return {
            "label": self.label,
            "largest_allgather_bytes": self.largest_allgather_bytes,
            "num_collectives": self.num_collectives,
            "alias_entries": self.alias_entries,
            "donated": self.donated,
            "findings": [f.to_dict() for f in self.findings],
        }


def _audit_compiled(lowered, *, label: str, embed_bytes: int,
                    donated: bool) -> StepAudit:
    compiled = lowered.compile()
    text = compiled.as_text()
    findings = hlo_lint.audit_hlo(text, label=label,
                                  max_allgather_bytes=embed_bytes)
    aliases = hlo_lint.input_output_aliases(text)
    if donated and not aliases:
        findings.append(Finding(
            label, 1, "hlo-missing-alias",
            "step was built with donated cache argnums but the compiled "
            "module has no input_output_alias entries — XLA is copying "
            "the cache every step"))
    return StepAudit(
        label=label,
        hlo_lines=text.count("\n") + 1,
        largest_allgather_bytes=hlo_lint.largest_allgather_bytes(text),
        num_collectives=len(hlo_lint.find_collectives(text)),
        alias_entries=len(aliases),
        donated=donated,
        findings=findings,
    )


def engagement_findings(delta: Dict[str, int], *, use_pallas: bool,
                        label: str) -> List[Finding]:
    """KERNEL_INVOCATIONS delta vs the route the engine was asked for."""
    hits = sum(delta.values())
    if use_pallas and hits == 0:
        return [Finding(
            label, 1, "kernel-engagement",
            "use_pallas=True but no kernels.ops wrapper traced — the "
            "serving path silently fell back to the jnp epilogues")]
    if not use_pallas and hits:
        names = ", ".join(sorted(k for k, v in delta.items() if v))
        return [Finding(
            label, 1, "kernel-engagement",
            f"use_pallas=False but Pallas wrappers traced ({names}) — "
            f"the jnp reference route is being bypassed")]
    return []


def audit_serving_step(cache_mode: str = "fp", use_pallas: bool = False,
                       seq_sharded: bool = False, *,
                       arch: str = "gpt2-small", batch: int = 2,
                       max_len: int = 64, prompt_len: int = 5,
                       max_new: int = 4,
                       donate: Optional[bool] = None
                       ) -> Tuple[List[Finding], dict]:
    """Audit the compiled decode_chunk + prefill_chunk for one combo.

    ``seq_sharded=True`` builds the engine on a mesh over every host
    device (1 when ``max_len`` does not divide) so the shard_map decode
    and chunked-prefill lowerings run through the same HLO auditors — in
    particular no embed/table-sized all-gather may appear on the mesh
    paths (the partial-stats merge moves (B, H)-sized stats only).

    Returns ``(findings, report)``; an empty findings list means the
    compiled artifacts hold every audited invariant for this combo.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as kops
    from repro.models import transformer as tlm
    from repro.serving import steps as serving_steps
    from repro.serving.engine import ServingEngine

    # lint: allow[cache-mode-dispatch] audit-matrix input, not layout dispatch
    astra = cache_mode in ("vq", "paged_vq")
    cfg, params = _small_model(arch, astra)
    mesh_kw = {}
    num_shards = 1
    if seq_sharded:
        from repro.compat import make_mesh
        from repro.core.sequence_parallel import MeshContext

        n = jax.device_count()
        num_shards = n if max_len % n == 0 else 1
        mesh_kw["mesh_ctx"] = MeshContext(
            mesh=make_mesh((num_shards,), ("model",)), batch_axes=(),
            seq_axis="model")
    eng = ServingEngine(cfg, params, max_len=max_len, astra_mode="off",
                        cache_mode=cache_mode, page_size=8, decode_chunk=2,
                        use_pallas=use_pallas, donate=donate, **mesh_kw)
    tag = (f"{cache_mode}{'+pallas' if use_pallas else ''}"
           f"{f'+mesh{num_shards}' if seq_sharded else ''}")

    before = dict(kops.KERNEL_INVOCATIONS)
    toks = np.tile(np.arange(1, prompt_len + 1, dtype=np.int32), (batch, 1))
    lens = np.full((batch,), prompt_len, np.int32)
    last_logits, caches, block_tables = eng._run_prefill(toks, lens, max_new)

    lengths = jnp.asarray(lens)
    lowered_decode = eng._decode_chunk.lower(
        eng.params, jnp.zeros((batch,), jnp.int32), caches, lengths,
        jnp.full((batch,), max_new, jnp.int32),
        jnp.full((batch,), -1, jnp.int32), jnp.zeros((batch,), bool),
        jax.random.PRNGKey(0), block_tables, num_steps=2, temperature=0.0,
        top_k=0)
    delta = {k: v - before.get(k, 0)
             for k, v in kops.KERNEL_INVOCATIONS.items()
             if v - before.get(k, 0)}

    leaf = jax.tree.leaves(params)[0]
    embed_bytes = cfg.vocab_size * cfg.d_model * leaf.dtype.itemsize
    audits = [_audit_compiled(
        lowered_decode, label=f"decode_chunk[{tag}]", embed_bytes=embed_bytes,
        donated=bool(eng._decode_chunk.donate_argnums))]

    if eng.prefill_mode == "chunked":
        if eng.backend.paged:
            kv = eng.backend.make_state(
                cfg, slots=batch, max_len=max_len, ctx=eng.decode_ctx,
                page_size=eng.page_size, dtype=eng.cache_dtype)
            for i in range(batch):
                kv_ok = eng.backend.advance(kv, i, prompt_len + max_new)
                assert kv_ok, "audit pool sized for its own slots"
            caches_p, tables = kv.init_cache(batch, prefill_scratch=True), \
                kv.tables()
        else:
            caches_p, tables = tlm.init_lm_cache(
                cfg, batch, max_len, eng.prefill_ctx, eng.cache_dtype,
                prefill_scratch=True), None
        w = serving_steps.plan_chunks(prompt_len, eng.prefill_buckets)[0][1]
        lowered_prefill = eng._prefill_chunk.lower(
            eng.params, jnp.zeros((batch, w), jnp.int32),
            jnp.asarray(0, jnp.int32), caches_p, lengths,
            jnp.zeros((batch, cfg.vocab_size), jnp.float32), tables,
            history_len=serving_steps.view_bucket(w, max_len))
        audits.append(_audit_compiled(
            lowered_prefill, label=f"prefill_chunk[{tag}]",
            embed_bytes=embed_bytes,
            donated=bool(eng._prefill_chunk.donate_argnums)))

    findings = [f for a in audits for f in a.findings]
    findings += engagement_findings(delta, use_pallas=use_pallas,
                                    label=f"serving_steps[{tag}]")
    report = {
        "arch": arch,
        "cache_mode": cache_mode,
        "use_pallas": use_pallas,
        "seq_sharded": seq_sharded,
        "num_shards": num_shards,
        "kernel_invocations": delta,
        "steps": [a.report() for a in audits],
    }
    return findings, report


def donation_aliasing_findings(donated, others, *, label: str
                               ) -> List[Finding]:
    """Leaf-identity audit of one jitted call's arguments: an array
    reachable from BOTH the donated argument and a non-donated one makes
    donation unsound — XLA may reuse the buffer for an output while the
    other argument still reads it.  This is a *host-side* check (python
    object identity), so it catches exactly the adopt-pools style aliasing
    the HLO auditors cannot see (by lowering time both references are one
    parameter or the damage is already done)."""
    import jax

    donated_ids: Dict[int, str] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(donated)[0]:
        if hasattr(leaf, "dtype"):
            donated_ids[id(leaf)] = jax.tree_util.keystr(path)
    findings: List[Finding] = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(others)[0]:
        if id(leaf) in donated_ids:
            findings.append(Finding(
                label, 1, "donation-aliasing",
                f"non-donated argument leaf {jax.tree_util.keystr(path)} "
                f"is the same buffer as donated leaf "
                f"{donated_ids[id(leaf)]} — donating it invalidates a "
                f"live input"))
    return findings


def audit_chunked_admission(cache_mode: str = "paged", *,
                            arch: str = "gpt2-small", max_len: int = 64,
                            prompt_len: int = 20, max_new: int = 2
                            ) -> Tuple[List[Finding], dict]:
    """Drive one real chunked admission through the continuous scheduler
    and audit every slot-merge call's donated-vs-rest argument aliasing
    (the donated live cache must not share buffers with the fresh batch-1
    tree — see ``scheduler._advance_pending``'s strip_pool_leaves)."""
    from repro.serving.scheduler import ContinuousBatchingEngine

    # lint: allow[cache-mode-dispatch] audit-matrix input, not layout dispatch
    astra = cache_mode in ("vq", "paged_vq")
    cfg, params = _small_model(arch, astra)
    eng = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=max_len, astra_mode="off",
        cache_mode=cache_mode, page_size=8, decode_chunk=2)
    label = f"merge_slot[{cache_mode}]"
    findings: List[Finding] = []
    merges = [0]
    real_merge = eng._merge

    def audited_merge(live, fresh, slot):
        merges[0] += 1
        # audit as-if-donated even where the platform filtered donation
        # out (CPU): the aliasing bug only bites on TPU/GPU, but the
        # invariant must hold everywhere the code ships
        findings.extend(donation_aliasing_findings(
            live, (fresh, slot), label=label))
        return real_merge(live, fresh, slot)

    eng._merge = audited_merge
    eng.submit(list(range(1, prompt_len + 1)), max_new_tokens=max_new)
    eng.run_until_drained()
    report = {
        "cache_mode": cache_mode,
        "merge_calls": merges[0],
        "findings": [f.to_dict() for f in findings],
    }
    return findings, report


def audit_matrix(matrix: Sequence[Tuple] = DEFAULT_MATRIX,
                 **kw) -> Tuple[List[Finding], List[dict]]:
    """Run :func:`audit_serving_step` over a (cache_mode, use_pallas[,
    seq_sharded]) matrix; returns merged findings + one report per combo."""
    findings: List[Finding] = []
    reports: List[dict] = []
    for cache_mode, use_pallas, *rest in matrix:
        seq_sharded = bool(rest[0]) if rest else False
        f, r = audit_serving_step(cache_mode, use_pallas, seq_sharded, **kw)
        findings.extend(f)
        reports.append(r)
    return findings, reports
