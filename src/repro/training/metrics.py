"""Training/serving metrics: JSONL logger + throughput meters.

Kept dependency-free (no tensorboard on this box); the JSONL stream is the
interchange format for dashboards.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional


class JsonlLogger:
    """Append-only JSONL metrics stream with a wall-clock column."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._t0 = time.time()
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            self._fh = open(path, "a", buffering=1)

    def log(self, step: int, **metrics: Any) -> Dict[str, Any]:
        rec = {"step": step, "wall_s": round(time.time() - self._t0, 3)}
        rec.update({k: (float(v) if hasattr(v, "__float__") else v)
                    for k, v in metrics.items()})
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
        return rec

    def close(self) -> None:
        if self._fh:
            self._fh.close()


class ThroughputMeter:
    """Tokens/sec + step-time EMA."""

    def __init__(self, ema: float = 0.9):
        self.ema = ema
        self._last = None
        self.step_s = 0.0
        self.tok_per_s = 0.0

    def tick(self, tokens: int) -> Dict[str, float]:
        now = time.time()
        if self._last is not None:
            dt = max(now - self._last, 1e-9)
            inst = tokens / dt
            a = self.ema if self.step_s else 0.0
            self.step_s = a * self.step_s + (1 - a) * dt
            self.tok_per_s = a * self.tok_per_s + (1 - a) * inst
        self._last = now
        return {"step_s": self.step_s, "tok_per_s": self.tok_per_s}


def mfu(tok_per_s: float, params: int, chips: int,
        peak_flops: float = 197e12, train: bool = True) -> float:
    """Model-FLOPs utilisation: achieved 6ND (or 2ND) flops / peak."""
    per_tok = (6.0 if train else 2.0) * params
    return tok_per_s * per_tok / (chips * peak_flops)
