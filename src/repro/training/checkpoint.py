"""Flat-npz checkpointing for arbitrary pytrees (no orbax on this box).

Leaves are stored under '/'-joined key paths; restore rebuilds into the
structure of a provided template pytree (shape/dtype checked).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save(path: str, tree, metadata: Dict[str, Any] | None = None) -> None:
    if not path.endswith(".npz"):
        path = path + ".npz"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f)


def restore(path: str, template):
    """Restore into the structure of ``template`` (shapes must match)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat = dict(data)

    def rebuild(t, prefix=""):
        if isinstance(t, dict):
            return {k: rebuild(t[k], f"{prefix}{k}/") for k in t}
        if isinstance(t, (list, tuple)):
            vals = [rebuild(v, f"{prefix}#{i}/") for i, v in enumerate(t)]
            return type(t)(vals) if isinstance(t, tuple) else vals
        key = prefix[:-1]
        arr = flat[key]
        want = np.asarray(t)
        if arr.shape != want.shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {want.shape}")
        return jax.numpy.asarray(arr.astype(want.dtype))

    return rebuild(template)


def load_metadata(path: str) -> Dict[str, Any]:
    if not path.endswith(".npz"):
        path = path + ".npz"
    with open(path + ".meta.json") as f:
        return json.load(f)
