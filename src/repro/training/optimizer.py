"""AdamW + LR schedules (self-contained; no optax on this box)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # bf16 first/second moments for memory-tight giants (llama3-405b, dbrx)
    state_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    prog = jnp.clip((s - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params, grads, state: Dict[str, Any], cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m2 / bc1
        vh = v2 / bc2
        step_v = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * step_v).astype(p.dtype),
                m2.astype(dt), v2.astype(dt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
