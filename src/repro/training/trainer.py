"""ASTRA fine-tuning loop (paper §3.2/§3.3 recipe, eq. 2).

Loss = task loss + beta * ||X - sg(X_hat)||^2 (commitment, per-element mean)
       + MoE aux loss.
Straight-through estimator + NAVQ noise live in the sim-mode forward; the
per-layer NAVQ residual statistics ride along as model state and are
EMA-updated every step.  Codebooks are trained by gradient (through the
dequantized attention path) — functionally equivalent to the paper's EMA
update; recorded as a deviation in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model_factory as mf
from repro.models.context import StepCtx
from repro.training import optimizer as opt_mod


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Dict[str, Any]
    navq: Any
    rng: jax.Array


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_train_step(cfg: ModelConfig, ctx: StepCtx,
                    opt_cfg: opt_mod.AdamWConfig) -> Callable:
    is_vit = cfg.arch_type == "vit"

    def loss_fn(params, batch, navq_state, rng):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, aux, new_navq = mf.forward(
            params, inputs, ctx=ctx, rng=rng, navq_state=navq_state)
        labels = batch["labels"]
        if is_vit:
            task = cross_entropy(logits, labels)
        else:
            # logits cover the concatenated stream for VLMs; score the tail
            t_lab = labels.shape[1]
            task = cross_entropy(logits[:, -t_lab:], labels)
        n_elts = jnp.asarray(labels.size, jnp.float32)
        commit = aux["commit"] / jnp.maximum(n_elts, 1.0)
        total = task + cfg.astra.commit_beta * commit + aux["moe_aux"]
        metrics = {"loss": total, "task_loss": task, "commit": commit,
                   "moe_aux": aux["moe_aux"]}
        return total, (metrics, new_navq)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        rng, sub = jax.random.split(state.rng)
        (_, (metrics, new_navq)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch, state.navq, sub)
        new_params, new_opt, om = opt_mod.adamw_update(
            state.params, grads, state.opt, opt_cfg)
        metrics.update(om)
        return TrainState(new_params, new_opt, new_navq, rng), metrics

    return jax.jit(train_step)


class Trainer:
    """Single-host trainer running the paper's simulated-N-device fine-tune."""

    def __init__(self, cfg: ModelConfig, *, num_devices_sim: int = 4,
                 opt_cfg: Optional[opt_mod.AdamWConfig] = None,
                 astra_mode: str = "sim", seed: int = 42):
        self.cfg = cfg
        self.ctx = StepCtx(cfg=cfg, mode="train", astra_mode=astra_mode,
                           train=True, num_sim_shards=num_devices_sim)
        self.opt_cfg = opt_cfg or opt_mod.AdamWConfig()
        key = jax.random.PRNGKey(seed)
        pkey, rkey = jax.random.split(key)
        params = mf.init_params(pkey, cfg)
        self.state = TrainState(
            params=params,
            opt=opt_mod.init_opt_state(params, self.opt_cfg),
            navq=mf.init_navq_state(cfg),
            rng=rkey,
        )
        self._step_fn = make_train_step(cfg, self.ctx, self.opt_cfg)

    def fit(self, data: Iterator[Dict], steps: int,
            log_every: int = 10, log: bool = True) -> List[Dict[str, float]]:
        history = []
        t0 = time.time()
        for i in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            self.state, metrics = self._step_fn(self.state, batch)
            if i % log_every == 0 or i == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i
                m["wall_s"] = time.time() - t0
                history.append(m)
                if log:
                    print(f"step {i:5d} loss {m['loss']:.4f} "
                          f"task {m['task_loss']:.4f} commit {m['commit']:.4f}")
        return history

    def eval_loss(self, data: Iterator[Dict], batches: int = 8) -> float:
        ctx_eval = dataclasses.replace(self.ctx, train=False)
        is_vit = self.cfg.arch_type == "vit"

        @jax.jit
        def eval_one(params, navq_state, batch):
            inputs = {k: v for k, v in batch.items() if k != "labels"}
            logits, _, _ = mf.forward(params, inputs, ctx=ctx_eval,
                                      navq_state=navq_state)
            labels = batch["labels"]
            if is_vit:
                return cross_entropy(logits, labels)
            return cross_entropy(logits[:, -labels.shape[1]:], labels)

        tot = 0.0
        for _ in range(batches):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            tot += float(eval_one(self.state.params, self.state.navq, batch))
        return tot / batches
