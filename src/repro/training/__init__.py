from repro.training import checkpoint, optimizer, trainer  # noqa: F401
